//! Persistent JSON plan cache.
//!
//! Planning is cheap analytically but expensive when empirically refined
//! (a `W_{o,b}` sweep runs real kernels dozens of times). Following the
//! amortize-setup-across-inferences idea of the indirect-convolution work,
//! the cache persists every decided [`LayerPlan`] to disk so a tuned plan
//! survives process restarts: the second run of `im2win plan`/`serve` (or
//! an engine construction) hits the cache and runs no tuning at all.
//!
//! Keys are `(geometry at the planning batch, incoming layout, thread
//! count)`. The thread count is whatever the deciding planner assumed —
//! for a sharded server that is the *per-shard* worker count
//! ([`super::Planner::for_shards`]), so an N-shard process and a
//! whole-machine process tuning the same geometry occupy distinct
//! entries instead of silently trading plans optimized for different
//! parallelism. The machine spec is deliberately *not* part of the key: the
//! cache persists same-host decisions across restarts, and a refining
//! planner upgrades analytic-only entries in place rather than trusting
//! them (see [`super::Planner::plan_model`]) — so `--refine` is honored
//! even against a warm cache. The *cost model* that decided the entries
//! is tracked separately: the cache stores the fingerprint of the
//! calibration profile (or `""` for the analytic constants) its entries
//! were scored under, and [`PlanCache::sync_profile`] drops every entry
//! when a planner with a different fingerprint consults it — a refit
//! invalidates stale plans instead of silently reusing them. The file
//! format is the repo's own zero-dependency JSON
//! ([`crate::config::json`]), written with sorted keys so serialization
//! is canonical: `save → load → save` produces byte-identical files
//! (pinned by a property test).
//!
//! Where this sits in the system — and which serving front consults it
//! when — is mapped in `docs/ARCHITECTURE.md`.

use super::graph::{ConversionPoint, GraphPlan};
use super::planner::LayerPlan;
use crate::config::json::{self, Json};
use crate::conv::{AlgoKind, ConvParams, Precision};
use crate::error::{Error, Result};
use crate::tensor::Layout;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Cache-file format version (bump on incompatible layout changes).
const VERSION: f64 = 1.0;

/// Canonical cache key for one layer decision: geometry at the planning
/// batch, the incoming activation layout, and the thread count.
///
/// Generalized geometry (padding, dilation, groups) appends a
/// `-p…-d…-g…` suffix **only when non-default**, so default-geometry
/// keys are byte-identical to pre-generalization cache files — old
/// entries keep serving the layers they were decided for, and can never
/// alias a padded/dilated/grouped layer (which always carries the
/// suffix).
pub fn layer_key(p: &ConvParams, prev: Layout, threads: usize) -> String {
    let geometry = if p.has_default_geometry() {
        String::new()
    } else {
        format!(
            "-p{}x{}-d{}x{}-g{}",
            p.pad_h, p.pad_w, p.dilation_h, p.dilation_w, p.groups
        )
    };
    format!(
        "n{}c{}x{}x{}-o{}f{}x{}s{}x{}{}-from_{}-t{}",
        p.n,
        p.c_in,
        p.h_in,
        p.w_in,
        p.c_out,
        p.h_f,
        p.w_f,
        p.stride_h,
        p.stride_w,
        geometry,
        prev.name(),
        threads
    )
}

/// Persistent key → [`LayerPlan`] store (see module docs).
#[derive(Debug, Default)]
pub struct PlanCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, LayerPlan>,
    /// Whole-graph entries ([`GraphPlan`]), keyed by
    /// [`super::graph::graph_key`] — model fingerprint, incoming layout,
    /// batch, threads. They live and die with the same profile
    /// fingerprint as the per-layer entries.
    graphs: BTreeMap<String, GraphPlan>,
    /// Fingerprint of the calibration profile the stored entries were
    /// decided under (empty = the analytic constants). See
    /// [`PlanCache::sync_profile`].
    profile: String,
    hits: usize,
    misses: usize,
}

impl PlanCache {
    /// A cache with no backing file (tests, one-shot runs).
    pub fn in_memory() -> Self {
        PlanCache::default()
    }

    /// Open the cache at `path`, loading existing entries; a missing file
    /// yields an empty cache that [`PlanCache::save`] will create.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut cache = PlanCache { path: Some(path.to_path_buf()), ..PlanCache::default() };
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let (profile, entries, graphs) = parse_document(&text)?;
            cache.profile = profile;
            cache.entries = entries;
            cache.graphs = graphs;
        }
        Ok(cache)
    }

    /// Open the cache at `path` like [`PlanCache::load`], but treat a
    /// corrupt file as recoverable instead of fatal: the unreadable file
    /// is quarantined to the first free `<path>.corrupt-<n>` sibling
    /// (n = 1, 2, …) for post-mortem inspection, and an empty cache
    /// bound to `path` is returned, so serving proceeds (re-planning,
    /// re-tuning, eventually re-saving) instead of refusing to start.
    /// The second element reports where the corrupt file went (`None`
    /// when the file loaded cleanly or did not exist — a missing file is
    /// not corruption). If the quarantine rename itself fails the
    /// corrupt file is left in place and the cache still starts empty.
    ///
    /// With the `fault-inject` feature, an armed `cache_corrupt` fault
    /// forces the corrupt path even for a healthy file — the
    /// deterministic hook the chaos tests use.
    pub fn load_or_recover(path: impl AsRef<Path>) -> (Self, Option<PathBuf>) {
        use super::faultinject::{self, FaultSite};
        let path = path.as_ref();
        let forced = faultinject::fire(FaultSite::CacheCorrupt).is_some();
        if !forced {
            if let Ok(cache) = Self::load(path) {
                return (cache, None);
            }
        }
        let empty = PlanCache { path: Some(path.to_path_buf()), ..PlanCache::default() };
        if !path.exists() {
            return (empty, None);
        }
        let mut n = 1usize;
        let dest = loop {
            let mut name = path.as_os_str().to_os_string();
            name.push(format!(".corrupt-{n}"));
            let candidate = PathBuf::from(name);
            if !candidate.exists() {
                break candidate;
            }
            n += 1;
        };
        match std::fs::rename(path, &dest) {
            Ok(()) => (empty, Some(dest)),
            Err(_) => (empty, None),
        }
    }

    /// Write the cache to its backing file (error if opened in-memory).
    /// Serialization is canonical — sorted keys, shortest-round-trip
    /// numbers — so repeated saves of equal content are byte-identical.
    pub fn save(&self) -> Result<()> {
        let path = self
            .path
            .as_ref()
            .ok_or_else(|| Error::Config("plan cache has no backing file".into()))?;
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json_text())?;
        Ok(())
    }

    /// Serialize to canonical JSON text.
    pub fn to_json_text(&self) -> String {
        let entries: Vec<(String, Json)> = self
            .entries
            .iter()
            .map(|(k, plan)| (k.clone(), plan_json(plan)))
            .collect();
        let graphs: Vec<(String, Json)> = self
            .graphs
            .iter()
            .map(|(k, graph)| (k.clone(), graph_json(graph)))
            .collect();
        Json::Object(vec![
            ("version".into(), Json::Number(VERSION)),
            ("profile".into(), Json::String(self.profile.clone())),
            ("entries".into(), Json::Object(entries)),
            ("graphs".into(), Json::Object(graphs)),
        ])
        .to_string()
    }

    /// Sync the cache to the calibration-profile fingerprint of the
    /// planner about to consult it (empty = analytic constants). A
    /// mismatch means every stored decision was scored under a different
    /// cost model, so the entries are dropped — re-planned, not silently
    /// reused — and the new fingerprint is recorded. Returns how many
    /// entries were invalidated (0 when the fingerprints already agree).
    pub fn sync_profile(&mut self, fingerprint: &str) -> usize {
        if self.profile == fingerprint {
            return 0;
        }
        let dropped = self.entries.len() + self.graphs.len();
        self.entries.clear();
        self.graphs.clear();
        self.profile = fingerprint.to_string();
        dropped
    }

    /// Fingerprint of the profile the stored entries were decided under
    /// (empty = the analytic constants).
    pub fn profile_fingerprint(&self) -> &str {
        &self.profile
    }

    /// Look up a whole-graph plan (key from [`super::graph::graph_key`]);
    /// counts a hit or miss.
    pub fn get_graph(&mut self, key: &str) -> Option<GraphPlan> {
        match self.graphs.get(key).cloned() {
            Some(g) => {
                self.hits += 1;
                Some(g)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) a whole-graph plan.
    pub fn insert_graph(&mut self, key: String, graph: GraphPlan) {
        self.graphs.insert(key, graph);
    }

    /// Number of stored whole-graph plans.
    pub fn graph_len(&self) -> usize {
        self.graphs.len()
    }

    /// Look up a plan; counts a hit or miss.
    pub fn get(&mut self, key: &str) -> Option<LayerPlan> {
        match self.entries.get(key).copied() {
            Some(p) => {
                self.hits += 1;
                Some(p)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) a plan.
    pub fn insert(&mut self, key: String, plan: LayerPlan) {
        self.entries.insert(key, plan);
    }

    /// Number of stored plans.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plans are stored.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lookups answered from the cache since load.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Lookups that found nothing since load.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// The backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

fn plan_json(p: &LayerPlan) -> Json {
    let mut fields = vec![
        ("algo", Json::from(p.algo.name())),
        ("layout", Json::from(p.layout.name())),
        ("w_block", Json::Number(p.w_block as f64)),
        ("est_s", Json::Number(p.est_s)),
        ("tuned", Json::Bool(p.tuned)),
    ];
    // Written only for reduced tiers: f32 entries stay byte-identical to
    // pre-precision cache files (pinned by a test), and old files load
    // as the f32 they were decided at.
    if p.precision.is_reduced() {
        fields.push(("precision", Json::from(p.precision.name())));
    }
    Json::object(fields)
}

fn parse_plan(v: &Json) -> Result<LayerPlan> {
    let bad = |what: &str| Error::Config(format!("plan cache entry: bad or missing '{what}'"));
    let algo_name = v.get("algo").and_then(Json::as_str).ok_or_else(|| bad("algo"))?;
    let layout_name = v.get("layout").and_then(Json::as_str).ok_or_else(|| bad("layout"))?;
    let precision = match v.get("precision") {
        None => Precision::F32,
        Some(j) => {
            let name = j.as_str().ok_or_else(|| bad("precision"))?;
            Precision::parse(name).ok_or_else(|| bad("precision"))?
        }
    };
    Ok(LayerPlan {
        algo: AlgoKind::parse(algo_name).ok_or_else(|| bad("algo"))?,
        layout: Layout::parse(layout_name).ok_or_else(|| bad("layout"))?,
        w_block: v.get("w_block").and_then(Json::as_f64).ok_or_else(|| bad("w_block"))? as usize,
        est_s: v.get("est_s").and_then(Json::as_f64).ok_or_else(|| bad("est_s"))?,
        tuned: v.get("tuned").and_then(Json::as_bool).ok_or_else(|| bad("tuned"))?,
        precision,
    })
}

fn graph_json(g: &GraphPlan) -> Json {
    let conversions: Vec<Json> = g
        .conversions
        .iter()
        .map(|c| {
            Json::object(vec![
                ("conv_index", Json::Number(c.conv_index as f64)),
                ("est_s", Json::Number(c.est_s)),
                ("from", Json::from(c.from.name())),
                ("to", Json::from(c.to.name())),
            ])
        })
        .collect();
    Json::object(vec![
        ("conversions", Json::Array(conversions)),
        ("plans", Json::Array(g.plans.iter().map(plan_json).collect())),
        ("total_s", Json::Number(g.total_s)),
    ])
}

fn parse_graph(v: &Json) -> Result<GraphPlan> {
    let bad = |what: &str| Error::Config(format!("plan cache graph entry: bad or missing '{what}'"));
    let plans = v
        .get("plans")
        .and_then(Json::as_array)
        .ok_or_else(|| bad("plans"))?
        .iter()
        .map(parse_plan)
        .collect::<Result<Vec<_>>>()?;
    let mut conversions = Vec::new();
    for c in v.get("conversions").and_then(Json::as_array).ok_or_else(|| bad("conversions"))? {
        let from = c.get("from").and_then(Json::as_str).ok_or_else(|| bad("from"))?;
        let to = c.get("to").and_then(Json::as_str).ok_or_else(|| bad("to"))?;
        conversions.push(ConversionPoint {
            conv_index: c
                .get("conv_index")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad("conv_index"))? as usize,
            from: Layout::parse(from).ok_or_else(|| bad("from"))?,
            to: Layout::parse(to).ok_or_else(|| bad("to"))?,
            est_s: c.get("est_s").and_then(Json::as_f64).ok_or_else(|| bad("est_s"))?,
        });
    }
    Ok(GraphPlan {
        plans,
        conversions,
        total_s: v.get("total_s").and_then(Json::as_f64).ok_or_else(|| bad("total_s"))?,
    })
}

/// Parse a cache document into its (profile fingerprint, entries, graphs)
/// parts. The `profile` and `graphs` fields are optional on read (older
/// files predate them) and always written, defaulting to the analytic
/// marker `""` and no graphs.
fn parse_document(
    text: &str,
) -> Result<(String, BTreeMap<String, LayerPlan>, BTreeMap<String, GraphPlan>)> {
    let doc = json::parse(text)?;
    let version = doc
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Config("plan cache: missing version".into()))?;
    if version != VERSION {
        return Err(Error::Config(format!("plan cache: unsupported version {version}")));
    }
    let profile = doc.get("profile").and_then(Json::as_str).unwrap_or_default().to_string();
    let obj = doc
        .get("entries")
        .and_then(Json::as_object)
        .ok_or_else(|| Error::Config("plan cache: missing entries object".into()))?;
    let mut map = BTreeMap::new();
    for (k, v) in obj {
        map.insert(k.clone(), parse_plan(v)?);
    }
    let mut graphs = BTreeMap::new();
    if let Some(gobj) = doc.get("graphs").and_then(Json::as_object) {
        for (k, v) in gobj {
            graphs.insert(k.clone(), parse_graph(v)?);
        }
    }
    Ok((profile, map, graphs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan(i: usize) -> LayerPlan {
        LayerPlan {
            algo: [AlgoKind::Im2win, AlgoKind::Direct, AlgoKind::Im2col][i % 3],
            layout: Layout::ALL[i % 4],
            w_block: [4, 6, 0][i % 3],
            est_s: 1.5e-3 * (i + 1) as f64,
            tuned: i % 2 == 0,
            precision: Precision::ALL[i % 4],
        }
    }

    #[test]
    fn layer_key_is_injective_over_its_fields() {
        let p = ConvParams::builder().batch(8).channels(3, 16).input(32, 32).filter(3, 3).stride(1).build().unwrap();
        let a = layer_key(&p, Layout::Nchw, 1);
        assert_ne!(a, layer_key(&p, Layout::Nhwc, 1));
        assert_ne!(a, layer_key(&p, Layout::Nchw, 4));
        assert_ne!(a, layer_key(&p.with_batch(16), Layout::Nchw, 1));
    }

    #[test]
    fn layer_key_separates_generalized_geometry() {
        let dense = ConvParams::builder().batch(8).channels(16, 16).input(14, 14).filter(3, 3).build().unwrap();
        let base = layer_key(&dense, Layout::Nchw, 2);
        // Default geometry keeps the pre-generalization key shape: a
        // pre-existing cache entry still serves the layer it described…
        assert!(!base.contains("-p"), "default geometry must not grow a suffix: {base}");
        // …and can never be served for padded/dilated/grouped variants.
        let padded = ConvParams::builder().batch(8).channels(16, 16).input(14, 14).filter(3, 3).pad(1).build().unwrap();
        let dilated = ConvParams::builder().batch(8).channels(16, 16).input(14, 14).filter(3, 3).dilation(2).build().unwrap();
        let grouped = ConvParams::builder().batch(8).channels(16, 16).input(14, 14).filter(3, 3).groups(4).build().unwrap();
        let depthwise =
            ConvParams::builder().batch(8).channels(16, 16).input(14, 14).filter(3, 3).pad(1).groups(16).build().unwrap();
        let keys: Vec<String> = [&padded, &dilated, &grouped, &depthwise]
            .iter()
            .map(|p| layer_key(p, Layout::Nchw, 2))
            .collect();
        for (i, k) in keys.iter().enumerate() {
            assert_ne!(*k, base, "variant {i} aliased the dense key");
            for other in &keys[i + 1..] {
                assert_ne!(k, other);
            }
        }
    }

    #[test]
    fn get_insert_and_counters() {
        let mut c = PlanCache::in_memory();
        assert!(c.get("k").is_none());
        c.insert("k".into(), sample_plan(0));
        assert_eq!(c.get("k"), Some(sample_plan(0)));
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert_eq!(c.len(), 1);
    }

    fn sample_graph() -> GraphPlan {
        GraphPlan {
            plans: vec![sample_plan(0), sample_plan(1), sample_plan(2)],
            conversions: vec![ConversionPoint {
                conv_index: 1,
                from: Layout::Nchw,
                to: Layout::Chwn8,
                est_s: 2.5e-4,
            }],
            total_s: 7.5e-3,
        }
    }

    #[test]
    fn text_round_trip_is_byte_identical() {
        let mut c = PlanCache::in_memory();
        c.sync_profile("0123456789abcdef");
        for i in 0..6 {
            c.insert(format!("key{i}"), sample_plan(i));
        }
        c.insert_graph("gkey".into(), sample_graph());
        let text1 = c.to_json_text();
        let mut back = PlanCache::in_memory();
        let (profile, entries, graphs) = parse_document(&text1).unwrap();
        back.profile = profile;
        back.entries = entries;
        back.graphs = graphs;
        assert_eq!(back.to_json_text(), text1);
        assert_eq!(back.profile_fingerprint(), "0123456789abcdef");
        for i in 0..6 {
            assert_eq!(back.get(&format!("key{i}")), Some(sample_plan(i)));
        }
        assert_eq!(back.get_graph("gkey"), Some(sample_graph()));
    }

    #[test]
    fn sync_profile_invalidates_on_fingerprint_change() {
        let mut c = PlanCache::in_memory();
        c.insert("a".into(), sample_plan(0));
        c.insert("b".into(), sample_plan(1));
        // Analytic → analytic: nothing to do.
        assert_eq!(c.sync_profile(""), 0);
        assert_eq!(c.len(), 2);
        // Analytic → calibrated: every analytic decision is stale.
        assert_eq!(c.sync_profile("fp1"), 2);
        assert!(c.is_empty());
        assert_eq!(c.profile_fingerprint(), "fp1");
        // Same fingerprint again: entries survive.
        c.insert("a".into(), sample_plan(2));
        assert_eq!(c.sync_profile("fp1"), 0);
        assert_eq!(c.get("a"), Some(sample_plan(2)));
        // Refit (new fingerprint): stale again.
        assert_eq!(c.sync_profile("fp2"), 1);
        assert!(c.is_empty());
    }

    #[test]
    fn profile_and_graphs_fields_are_optional_on_read() {
        // Older cache files carry no 'profile' or 'graphs' field; they
        // load as analytic ("") caches with no graph plans.
        let text = r#"{"version": 1, "entries": {}}"#;
        let (profile, entries, graphs) = parse_document(text).unwrap();
        assert_eq!(profile, "");
        assert!(entries.is_empty());
        assert!(graphs.is_empty());
    }

    #[test]
    fn sync_profile_drops_graphs_too() {
        let mut c = PlanCache::in_memory();
        c.insert("a".into(), sample_plan(0));
        c.insert_graph("g".into(), sample_graph());
        assert_eq!(c.graph_len(), 1);
        // One layer entry + one graph entry invalidated together.
        assert_eq!(c.sync_profile("fp1"), 2);
        assert_eq!(c.graph_len(), 0);
        assert!(c.get_graph("g").is_none());
    }

    #[test]
    fn save_and_load_via_file() {
        let dir = std::env::temp_dir().join(format!("im2win_plancache_{}", std::process::id()));
        let path = dir.join("plans.json");
        let mut c = PlanCache::load(&path).unwrap();
        assert!(c.is_empty());
        c.insert("a".into(), sample_plan(1));
        c.path = Some(path.clone());
        c.save().unwrap();
        let mut again = PlanCache::load(&path).unwrap();
        assert_eq!(again.get("a"), Some(sample_plan(1)));
        assert!(PlanCache::in_memory().save().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_recover_quarantines_corrupt_files_and_numbers_them() {
        // With fault injection compiled in, load_or_recover probes the
        // cache_corrupt site; hold the registry lock so a concurrent
        // test's armed schedule cannot force-corrupt our healthy file.
        #[cfg(feature = "fault-inject")]
        let _guard = crate::engine::faultinject::test_lock();
        let dir =
            std::env::temp_dir().join(format!("im2win_plancache_recover_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plans.json");
        // A missing file is not corruption.
        let (c, q) = PlanCache::load_or_recover(&path);
        assert!(c.is_empty() && q.is_none());
        // A healthy file loads with no quarantine.
        let mut c = PlanCache::load(&path).unwrap();
        c.insert("a".into(), sample_plan(1));
        c.save().unwrap();
        let (mut c, q) = PlanCache::load_or_recover(&path);
        assert_eq!(c.get("a"), Some(sample_plan(1)));
        assert!(q.is_none());
        // Corruption quarantines to .corrupt-1 and starts empty…
        std::fs::write(&path, "{definitely not json").unwrap();
        let (c, q) = PlanCache::load_or_recover(&path);
        assert!(c.is_empty());
        let q1 = q.expect("corrupt file must be quarantined");
        assert!(q1.to_string_lossy().ends_with("plans.json.corrupt-1"), "{q1:?}");
        assert!(q1.exists() && !path.exists());
        // …and the recovered cache can save to the original path.
        c.save().unwrap();
        assert!(path.exists());
        // A second corruption picks the next free number.
        std::fs::write(&path, "also not json").unwrap();
        let (_, q) = PlanCache::load_or_recover(&path);
        assert!(q.unwrap().to_string_lossy().ends_with("plans.json.corrupt-2"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f32_entry_bytes_are_pinned_to_the_pre_precision_format() {
        // An f32 plan serializes with no 'precision' field at all — the
        // exact bytes the format wrote before the precision axis existed,
        // so old cache files and new f32 caches are interchangeable.
        let plan = LayerPlan {
            algo: AlgoKind::Im2win,
            layout: Layout::Nhwc,
            w_block: 4,
            est_s: 1.5e-3,
            tuned: true,
            precision: Precision::F32,
        };
        let mut c = PlanCache::in_memory();
        c.insert("k".into(), plan);
        let text = c.to_json_text();
        assert!(!text.contains("precision"), "f32 entry leaked a precision field: {text}");
        // A reduced-tier entry carries the field and round-trips it.
        let f16 = LayerPlan { precision: Precision::F16AccF32, ..plan };
        c.insert("k".into(), f16);
        let text = c.to_json_text();
        assert!(text.contains(r#""precision""#) && text.contains(r#""f16""#), "{text}");
        let (_, entries, _) = parse_document(&text).unwrap();
        assert_eq!(entries["k"], f16);
        // Files that predate the field load as the f32 they were.
        let old = r#"{"version": 1, "entries": {"k": {"algo": "im2win", "est_s": 0.0015, "layout": "nhwc", "tuned": true, "w_block": 4}}}"#;
        let (_, entries, _) = parse_document(old).unwrap();
        assert_eq!(entries["k"], plan);
        // An unknown tier name is corruption, not a silent f32.
        let bad = r#"{"version": 1, "entries": {"k": {"algo": "im2win", "est_s": 0.0015, "layout": "nhwc", "precision": "f8", "tuned": true, "w_block": 4}}}"#;
        assert!(parse_document(bad).is_err());
    }

    #[test]
    fn forced_f16_plans_never_serve_f32_requests() {
        use super::super::planner::Planner;
        let p = ConvParams::builder().batch(8).channels(64, 64).input(28, 28).filter(3, 3).stride(1).build().unwrap();
        let auto = Planner::new();
        let forced = Planner { precision: Some(Precision::F16AccF32), ..Planner::new() };
        let mut c = PlanCache::in_memory();
        let f16_plan = forced.plan_conv(&p, Layout::Nhwc);
        assert_eq!(f16_plan.precision, Precision::F16AccF32);
        c.insert(forced.cache_key(&p, Layout::Nhwc), f16_plan);
        // The default planner's lookup must miss — a halved-precision
        // decision can never be handed to a caller at the 1e-4 bar.
        assert_eq!(c.get(&auto.cache_key(&p, Layout::Nhwc)), None);
        assert_eq!((c.hits(), c.misses()), (0, 1));
        // The forced planner round-trips its own entry.
        assert_eq!(c.get(&forced.cache_key(&p, Layout::Nhwc)), Some(f16_plan));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse_document("[]").is_err());
        assert!(parse_document(r#"{"version": 99, "entries": {}}"#).is_err());
        // "winograd" is a real algorithm now — use a genuinely unknown name.
        assert!(
            parse_document(r#"{"version": 1, "entries": {"k": {"algo": "fft"}}}"#).is_err()
        );
        assert!(parse_document(r#"{"version": 1}"#).is_err());
    }
}
