//! Reusable scratch arena for the inference engine.
//!
//! Every convolution algorithm except direct needs per-call scratch — the
//! im2win window tensor, the im2col/MEC lowered matrices, packed filters —
//! and the engine's forward pass needs one activation buffer per layer.
//! The seed code allocated all of these on every `forward`; a serving
//! process doing thousands of identical-geometry requests pays that
//! allocation (and page-fault) cost over and over.
//!
//! [`Workspace`] is a keyed lease arena: callers [`Workspace::take`] a
//! buffer by `(tag, len)`, use it, and [`Workspace::put`] it back. The
//! first request for a key allocates the buffer (a *miss*); every later
//! request of the same key reuses it (a *hit*), so steady state performs
//! no tensor/scratch allocation. (The *keys* are small `String`s built
//! per lease — a few dozen bytes per layer, negligible next to the
//! megabyte-scale buffers this arena exists to recycle; interning them is
//! a possible follow-on.) Keys include the length, so the same tag at two
//! geometries (e.g. two conv layers sharing a scratch role) occupies two
//! slots instead of thrashing.
//!
//! Buffers are returned **dirty** — contents are whatever the previous
//! user left. Every kernel routed through the arena fully overwrites its
//! scratch (the im2win transform and the im2col/MEC lowerings write every
//! element; the im2win/direct kernels store every output element exactly
//! once, and the GEMM-backed paths zero their accumulation target first),
//! which the stale-scratch property tests in `tests/engine.rs` and
//! `tests/fused_epilogue.rs` pin down. (The async front applies the
//! same recycle-don't-allocate discipline to its completion slots —
//! see [`super::async_front`] — so the whole request path, submission
//! included, is allocation-free in steady state.)

use crate::tensor::{AlignedBuf, Dims, Layout, Tensor4};
use std::collections::HashMap;

/// A keyed arena of reusable aligned buffers (see module docs).
#[derive(Debug, Default)]
pub struct Workspace {
    slots: HashMap<(String, usize), AlignedBuf>,
    hits: usize,
    misses: usize,
}

impl Workspace {
    /// An empty arena.
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Lease a buffer of exactly `len` floats under `tag`.
    ///
    /// Returns the previously [`Workspace::put`] buffer for `(tag, len)`
    /// when available (a hit), otherwise allocates a zeroed one (a miss).
    /// Leased buffers may contain stale data on hits; callers must fully
    /// overwrite what they read.
    pub fn take(&mut self, tag: &str, len: usize) -> AlignedBuf {
        match self.slots.remove(&(tag.to_string(), len)) {
            Some(buf) => {
                self.hits += 1;
                buf
            }
            None => {
                self.misses += 1;
                AlignedBuf::zeroed(len)
            }
        }
    }

    /// Return a leased buffer so the next [`Workspace::take`] of the same
    /// `(tag, len)` reuses it.
    pub fn put(&mut self, tag: &str, buf: AlignedBuf) {
        let len = buf.len();
        self.slots.insert((tag.to_string(), len), buf);
    }

    /// Lease a tensor of `dims` × `layout` under `tag` (storage possibly
    /// stale — see [`Workspace::take`]).
    pub fn take_tensor(&mut self, tag: &str, dims: Dims, layout: Layout) -> Tensor4 {
        let buf = self.take(tag, layout.storage_len(dims));
        Tensor4::from_parts(buf, dims, layout)
    }

    /// Return a leased tensor's storage to the arena.
    pub fn put_tensor(&mut self, tag: &str, t: Tensor4) {
        self.put(tag, t.into_parts());
    }

    /// Number of lease requests served from the arena.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Number of lease requests that had to allocate.
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Number of parked (not currently leased) buffers.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no buffers are parked.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Total bytes parked in the arena right now.
    pub fn parked_bytes(&self) -> usize {
        self.slots.values().map(|b| b.len() * std::mem::size_of::<f32>()).sum()
    }

    /// Drop every parked buffer and reset the hit/miss counters.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_reuses_the_same_allocation() {
        let mut ws = Workspace::new();
        let mut a = ws.take("x", 128);
        let ptr = a.as_ptr();
        a[0] = 42.0;
        ws.put("x", a);
        let b = ws.take("x", 128);
        assert_eq!(b.as_ptr(), ptr, "expected the identical allocation back");
        assert_eq!(b[0], 42.0, "contents come back dirty by design");
        assert_eq!(ws.hits(), 1);
        assert_eq!(ws.misses(), 1);
    }

    #[test]
    fn different_lengths_use_distinct_slots() {
        let mut ws = Workspace::new();
        let a = ws.take("x", 64);
        ws.put("x", a);
        let b = ws.take("x", 128); // miss: same tag, new length
        assert_eq!(ws.misses(), 2);
        ws.put("x", b);
        let _ = ws.take("x", 64); // both sizes now parked: hit
        let _ = ws.take("x", 128); // hit
        assert_eq!(ws.hits(), 2);
    }

    #[test]
    fn tensor_round_trip_all_layouts() {
        let dims = Dims::new(9, 3, 4, 5); // 9 exercises CHWN8 padding
        let mut ws = Workspace::new();
        for layout in Layout::ALL {
            let mut t = ws.take_tensor("act", dims, layout);
            assert_eq!(t.dims(), dims);
            assert_eq!(t.layout(), layout);
            t.set(8, 2, 3, 4, 7.0);
            ws.put_tensor("act", t);
        }
        // Four layouts, but NCHW/NHWC/CHWN share a storage length, so
        // they alias one slot; CHWN8 (padded) gets its own.
        assert!(ws.len() <= 2);
        assert!(ws.parked_bytes() > 0);
        ws.clear();
        assert!(ws.is_empty());
        assert_eq!(ws.hits() + ws.misses(), 0);
    }
}
