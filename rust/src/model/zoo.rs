//! Ready-made models.
//!
//! * [`tinynet`] — the CIFAR-scale CNN that mirrors the JAX model in
//!   `python/compile/model.py` layer-for-layer; the E2E example trains the
//!   JAX version through PJRT and then runs inference through this one to
//!   prove the two stacks agree.
//! * [`vgg_stack`] — a VGG-style chain built from the paper's conv7–conv12
//!   geometry family (3×3, stride 1, doubling channels with 2×2 pools),
//!   used by the `cnn_inference` example to exercise realistic depth.
//! * [`mixnet`] — a layout-diverse stack (narrow-channel stem, wide-
//!   channel tail) whose optimal layout assignment is mixed: the showcase
//!   for graph-level planning ([`crate::engine::graph`]).
//! * [`mobilenet_v1`] — a MobileNet-v1-class depthwise-separable CNN
//!   (strided padded stem, alternating depthwise 3×3 / pointwise 1×1
//!   blocks): the generalized-geometry showcase — every block exercises
//!   padding, groups and the planner's depthwise specialist.

use super::Model;
use crate::conv::{AlgoKind, ConvParams};
use crate::error::Result;
use crate::tensor::{Layout, Tensor4};
use crate::testutil::Rng;

/// Deterministic filter with a He-like scale for stable activations.
/// Fan-in is the *per-group* channel count — a depthwise tap sees one
/// channel, not `C_i`.
fn filter(p: &ConvParams, seed: u64) -> Tensor4 {
    let scale = (2.0 / (p.group_c_in() * p.h_f * p.w_f) as f32).sqrt();
    let mut rng = Rng::new(seed);
    Tensor4::from_fn(p.filter_dims(), Layout::Nchw, |_, _, _, _| rng.f32() * scale)
}

/// CIFAR-scale CNN (~19k parameters): mirrors `python/compile/model.py`.
///
/// ```text
/// 3×32×32 → conv3×3(16) → ReLU → pool2
///         → conv3×3(32) → ReLU → pool2
///         → conv3×3(32) → ReLU → GAP → linear(10)
/// ```
pub fn tinynet(layout: Layout, algo: AlgoKind, seed: u64) -> Result<Model> {
    let p1 = ConvParams::builder().batch(1).channels(3, 16).input(32, 32).filter(3, 3).stride(1).build()?;
    let p2 = ConvParams::builder().batch(1).channels(16, 32).input(15, 15).filter(3, 3).stride(1).build()?;
    let p3 = ConvParams::builder().batch(1).channels(32, 32).input(6, 6).filter(3, 3).stride(1).build()?;
    let mut rng = Rng::new(seed ^ 0xF00D);
    let head: Vec<f32> = (0..32 * 10).map(|_| rng.f32() * 0.1).collect();
    Model::new("tinynet", layout, 3, 32, 32)
        .conv(p1, algo, &filter(&p1, seed + 1))?
        .relu()
        .max_pool(2, 2)?
        .conv(p2, algo, &filter(&p2, seed + 2))?
        .relu()
        .max_pool(2, 2)?
        .conv(p3, algo, &filter(&p3, seed + 3))?
        .relu()
        .global_avg_pool()
        .linear(head, 10)
}

/// [`tinynet`] with a per-channel bias on every convolution — the model
/// that exercises (and benchmarks) the engine's fused bias+ReLU epilogue
/// path. Same geometry and filters as `tinynet(layout, algo, seed)`.
pub fn tinynet_biased(layout: Layout, algo: AlgoKind, seed: u64) -> Result<Model> {
    let p1 = ConvParams::builder().batch(1).channels(3, 16).input(32, 32).filter(3, 3).stride(1).build()?;
    let p2 = ConvParams::builder().batch(1).channels(16, 32).input(15, 15).filter(3, 3).stride(1).build()?;
    let p3 = ConvParams::builder().batch(1).channels(32, 32).input(6, 6).filter(3, 3).stride(1).build()?;
    let mut rng = Rng::new(seed ^ 0xF00D);
    let head: Vec<f32> = (0..32 * 10).map(|_| rng.f32() * 0.1).collect();
    let mut brng = Rng::new(seed ^ 0xB1A5);
    let mut bias = |c: usize| -> Vec<f32> { (0..c).map(|_| brng.f32() * 0.2).collect() };
    let (b1, b2, b3) = (bias(16), bias(32), bias(32));
    Model::new("tinynet_biased", layout, 3, 32, 32)
        .conv_bias(p1, algo, &filter(&p1, seed + 1), &b1)?
        .relu()
        .max_pool(2, 2)?
        .conv_bias(p2, algo, &filter(&p2, seed + 2), &b2)?
        .relu()
        .max_pool(2, 2)?
        .conv_bias(p3, algo, &filter(&p3, seed + 3), &b3)?
        .relu()
        .global_avg_pool()
        .linear(head, 10)
}

/// VGG-style stack from the paper's 3×3/stride-1 layer family, at an
/// `edge×edge` input (use 64 for a quick run, 224 for realism).
pub fn vgg_stack(layout: Layout, algo: AlgoKind, edge: usize, seed: u64) -> Result<Model> {
    // conv7-like: 3 -> 64
    let p1 = ConvParams::builder().batch(1).channels(3, 64).input(edge, edge).filter(3, 3).stride(1).build()?;
    let e1 = p1.h_out() / 2; // after pool
    // conv8-like: 64 -> 128
    let p2 = ConvParams::builder().batch(1).channels(64, 128).input(e1, e1).filter(3, 3).stride(1).build()?;
    let e2 = p2.h_out() / 2;
    // conv10-like: 128 -> 128
    let p3 = ConvParams::builder().batch(1).channels(128, 128).input(e2, e2).filter(3, 3).stride(1).build()?;
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let head: Vec<f32> = (0..128 * 10).map(|_| rng.f32() * 0.05).collect();
    Model::new("vgg_stack", layout, 3, edge, edge)
        .conv(p1, algo, &filter(&p1, seed + 10))?
        .relu()
        .max_pool(2, 2)?
        .conv(p2, algo, &filter(&p2, seed + 11))?
        .relu()
        .max_pool(2, 2)?
        .conv(p3, algo, &filter(&p3, seed + 12))?
        .relu()
        .global_avg_pool()
        .linear(head, 10)
}

/// Layout-diverse stack built to make graph-level planning non-trivial:
/// a wide-spatial, narrow-channel stem (3→6 channels at 5×5, then 6→64
/// at 3×3 — both starve the NHWC vector dimension, favoring CHWN8's
/// batch-major lanes) feeding a wide-channel tail (64→128 at 3×3, where
/// NHWC saturates its lanes and wins).
///
/// ```text
/// 3×40×40 → conv5×5(6)   → ReLU
///         → conv3×3(64)  → ReLU → pool2
///         → conv3×3(128) → ReLU → GAP → linear(10)
/// ```
///
/// The greedy per-layer planner is trapped here (at the planner's
/// default batch 8 and 4 threads): converting the stem to CHWN8 does not
/// pay for itself within conv1 alone — its 6 output channels are too few
/// — so the greedy chain leaves conv1 in the model layout and converts
/// twice later. The exact graph DP sees that one conversion amortizes
/// over *both* stem layers and assigns `CHWN8, CHWN8, NHWC`: a provably
/// mixed optimum that strictly beats the greedy chain
/// ([`crate::engine::graph`]).
pub fn mixnet(layout: Layout, algo: AlgoKind, seed: u64) -> Result<Model> {
    let p1 = ConvParams::builder().batch(1).channels(3, 6).input(40, 40).filter(5, 5).stride(1).build()?;
    let p2 = ConvParams::builder().batch(1).channels(6, 64).input(36, 36).filter(3, 3).stride(1).build()?;
    let p3 = ConvParams::builder().batch(1).channels(64, 128).input(17, 17).filter(3, 3).stride(1).build()?;
    let mut rng = Rng::new(seed ^ 0xD1CE);
    let head: Vec<f32> = (0..128 * 10).map(|_| rng.f32() * 0.05).collect();
    Model::new("mixnet", layout, 3, 40, 40)
        .conv(p1, algo, &filter(&p1, seed + 21))?
        .relu()
        .conv(p2, algo, &filter(&p2, seed + 22))?
        .relu()
        .max_pool(2, 2)?
        .conv(p3, algo, &filter(&p3, seed + 23))?
        .relu()
        .global_avg_pool()
        .linear(head, 10)
}

/// MobileNet-v1-class depthwise-separable CNN at CIFAR scale (~11 conv
/// layers): a strided, padded 3×3 stem followed by five depthwise-
/// separable blocks — depthwise 3×3 (pad 1, `groups == C`) then
/// pointwise 1×1 — two of them striding the spatial extent down, ending
/// in GAP + linear(10).
///
/// ```text
/// 3×32×32 → conv3×3 s2 p1 (16)            → ReLU        → 16×16×16
///         → [dw3×3 p1 → pw1×1] ×5                        (s2 at blocks
///            16→32, 32→64 (s2), 64→64, 64→128 (s2), 128→128)
///         → GAP → linear(10)
/// ```
///
/// Every depthwise layer satisfies [`ConvParams::is_depthwise`], so a
/// planner offered this model can (and does) pick the dedicated
/// depthwise kernels; the pointwise layers are ordinary dense 1×1 convs.
pub fn mobilenet_v1(layout: Layout, algo: AlgoKind, seed: u64) -> Result<Model> {
    // Per block: (channels in, pointwise channels out, depthwise stride).
    const BLOCKS: [(usize, usize, usize); 5] =
        [(16, 32, 1), (32, 64, 2), (64, 64, 1), (64, 128, 2), (128, 128, 1)];
    let stem = ConvParams::builder().channels(3, 16).input(32, 32).filter(3, 3).stride(2).pad(1).build()?;
    let mut edge = stem.h_out();
    let mut m = Model::new("mobilenet_v1", layout, 3, 32, 32)
        .conv(stem, algo, &filter(&stem, seed + 31))?
        .relu();
    let mut s = seed + 32;
    for (c, c_next, stride) in BLOCKS {
        let dw = ConvParams::builder()
            .channels(c, c)
            .input(edge, edge)
            .filter(3, 3)
            .stride(stride)
            .pad(1)
            .groups(c)
            .build()?;
        edge = dw.h_out();
        let pw = ConvParams::builder().channels(c, c_next).input(edge, edge).filter(1, 1).build()?;
        m = m
            .conv(dw, algo, &filter(&dw, s))?
            .relu()
            .conv(pw, algo, &filter(&pw, s + 1))?
            .relu();
        s += 2;
    }
    let mut rng = Rng::new(seed ^ 0x0B11E);
    let head: Vec<f32> = (0..128 * 10).map(|_| rng.f32() * 0.05).collect();
    m.global_avg_pool().linear(head, 10)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Dims;

    #[test]
    fn tinynet_shapes() {
        let m = tinynet(Layout::Nhwc, AlgoKind::Im2win, 1).unwrap();
        assert_eq!(m.out_dims().unwrap(), Dims::new(1, 10, 1, 1));
        let x = Tensor4::random(Dims::new(4, 3, 32, 32), Layout::Nhwc, 2);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.dims(), Dims::new(4, 10, 1, 1));
    }

    #[test]
    fn tinynet_agrees_across_algorithms() {
        let x = Tensor4::random(Dims::new(2, 3, 32, 32), Layout::Nchw, 3);
        let base = tinynet(Layout::Nchw, AlgoKind::Naive, 9).unwrap().forward(&x).unwrap();
        for algo in AlgoKind::BENCHED {
            for layout in [Layout::Nhwc, Layout::Chwn8] {
                let m = tinynet(layout, algo, 9).unwrap();
                let y = m.forward(&x).unwrap();
                assert!(
                    base.allclose(&y, 1e-3, 1e-4),
                    "{algo} {layout}: diff {}",
                    base.max_abs_diff(&y)
                );
            }
        }
    }

    #[test]
    fn tinynet_biased_agrees_across_algorithms() {
        let x = Tensor4::random(Dims::new(2, 3, 32, 32), Layout::Nchw, 12);
        let base = tinynet_biased(Layout::Nchw, AlgoKind::Naive, 9).unwrap().forward(&x).unwrap();
        // The bias must actually matter (otherwise the fused-epilogue
        // tests exercise nothing).
        let unbiased = tinynet(Layout::Nchw, AlgoKind::Naive, 9).unwrap().forward(&x).unwrap();
        assert!(base.max_abs_diff(&unbiased) > 1e-4, "bias had no effect");
        for algo in AlgoKind::BENCHED {
            let m = tinynet_biased(Layout::Nhwc, algo, 9).unwrap();
            let y = m.forward(&x).unwrap();
            assert!(
                base.allclose(&y, 1e-3, 1e-4),
                "{algo}: diff {}",
                base.max_abs_diff(&y)
            );
        }
    }

    #[test]
    fn mixnet_shapes_and_parity() {
        let m = mixnet(Layout::Nchw, AlgoKind::Naive, 7).unwrap();
        assert_eq!(m.out_dims().unwrap(), Dims::new(1, 10, 1, 1));
        let x = Tensor4::random(Dims::new(2, 3, 40, 40), Layout::Nchw, 8);
        let base = m.forward(&x).unwrap();
        assert_eq!(base.dims(), Dims::new(2, 10, 1, 1));
        for algo in AlgoKind::BENCHED {
            for layout in [Layout::Nhwc, Layout::Chwn8] {
                let y = mixnet(layout, algo, 7).unwrap().forward(&x).unwrap();
                assert!(
                    base.allclose(&y, 1e-3, 1e-4),
                    "{algo} {layout}: diff {}",
                    base.max_abs_diff(&y)
                );
            }
        }
    }

    #[test]
    fn mobilenet_shapes_and_depthwise_structure() {
        let m = mobilenet_v1(Layout::Nchw, AlgoKind::Naive, 6).unwrap();
        assert_eq!(m.out_dims().unwrap(), Dims::new(1, 10, 1, 1));
        let params = m.conv_params();
        assert_eq!(params.len(), 11); // stem + 5 × (depthwise + pointwise)
        let dw: Vec<_> = params.iter().filter(|p| p.is_depthwise()).collect();
        assert_eq!(dw.len(), 5, "every block leads with a depthwise layer");
        assert!(dw.iter().all(|p| p.pad_h == 1 && p.h_f == 3));
        // The stem is strided and padded but dense.
        assert!(params[0].stride_h == 2 && params[0].pad_h == 1 && params[0].groups == 1);
    }

    #[test]
    fn mobilenet_agrees_across_algorithms() {
        let x = Tensor4::random(Dims::new(2, 3, 32, 32), Layout::Nchw, 5);
        let base = mobilenet_v1(Layout::Nchw, AlgoKind::Naive, 6).unwrap().forward(&x).unwrap();
        assert_eq!(base.dims(), Dims::new(2, 10, 1, 1));
        for algo in AlgoKind::BENCHED {
            for layout in [Layout::Nhwc, Layout::Chwn8] {
                let y = mobilenet_v1(layout, algo, 6).unwrap().forward(&x).unwrap();
                assert!(
                    base.allclose(&y, 1e-3, 1e-4),
                    "{algo} {layout}: diff {}",
                    base.max_abs_diff(&y)
                );
            }
        }
    }

    #[test]
    fn vgg_stack_builds_at_64() {
        let m = vgg_stack(Layout::Nhwc, AlgoKind::Im2win, 64, 4).unwrap();
        assert_eq!(m.out_dims().unwrap(), Dims::new(1, 10, 1, 1));
        assert!(m.flops(1).unwrap() > 100_000_000); // deep enough to matter
    }
}
