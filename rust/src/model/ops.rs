//! Non-convolution model operators (ReLU, pooling, linear).
//!
//! These are supporting ops for the model runner, vectorized where the
//! layout gives unit-stride access but deliberately simple — the paper's
//! subject is the convolutions.

use crate::error::{Error, Result};
use crate::simd::{F32x8, LANES};
use crate::tensor::{Dims, Tensor4};
#[cfg(test)]
use crate::tensor::Layout;

/// Elementwise `max(x, 0)` in place (operates on raw storage: padding
/// lanes of CHWN8 are zeros and stay zeros under ReLU).
pub fn relu_inplace(x: &mut Tensor4) {
    let data = x.data_mut();
    let n = data.len();
    let nv = n - n % LANES;
    let zero = F32x8::zero();
    let mut i = 0;
    while i < nv {
        // SAFETY: i + 8 <= n.
        unsafe {
            F32x8::load(data.as_ptr().add(i)).max(zero).store(data.as_mut_ptr().add(i));
        }
        i += LANES;
    }
    for v in &mut data[nv..] {
        *v = v.max(0.0);
    }
}

/// Elementwise ReLU into a fresh tensor.
pub fn relu(x: &Tensor4) -> Tensor4 {
    let mut y = x.clone();
    relu_inplace(&mut y);
    y
}

/// Valid max pooling with square window `k`, stride `s`.
pub fn max_pool2d(x: &Tensor4, k: usize, s: usize) -> Result<Tensor4> {
    let d = x.dims();
    if k == 0 || s == 0 || k > d.h || k > d.w {
        return Err(Error::ShapeMismatch(format!("maxpool k={k} s={s} on {d}")));
    }
    let out_d = Dims::new(d.n, d.c, (d.h - k) / s + 1, (d.w - k) / s + 1);
    let mut y = Tensor4::zeros(out_d, x.layout());
    max_pool2d_into(x, k, s, &mut y)?;
    Ok(y)
}

/// Max pooling into a caller-provided output (the engine's reuse path).
/// `y` must be the pooled dims in `x`'s layout; every logical element is
/// overwritten, so recycled storage is safe.
pub fn max_pool2d_into(x: &Tensor4, k: usize, s: usize, y: &mut Tensor4) -> Result<()> {
    let d = x.dims();
    if k == 0 || s == 0 || k > d.h || k > d.w {
        return Err(Error::ShapeMismatch(format!("maxpool k={k} s={s} on {d}")));
    }
    let out_d = Dims::new(d.n, d.c, (d.h - k) / s + 1, (d.w - k) / s + 1);
    if y.dims() != out_d || y.layout() != x.layout() {
        return Err(Error::ShapeMismatch(format!(
            "maxpool output {} ({}) != expected {out_d} ({})",
            y.dims(),
            y.layout(),
            x.layout()
        )));
    }
    for n in 0..d.n {
        for c in 0..d.c {
            for ho in 0..out_d.h {
                for wo in 0..out_d.w {
                    let mut m = f32::NEG_INFINITY;
                    for u in 0..k {
                        for v in 0..k {
                            m = m.max(x.get(n, c, ho * s + u, wo * s + v));
                        }
                    }
                    y.set(n, c, ho, wo, m);
                }
            }
        }
    }
    Ok(())
}

/// Mean over all `(h, w)` positions, producing `(n, c, 1, 1)`.
pub fn global_avg_pool(x: &Tensor4) -> Tensor4 {
    let d = x.dims();
    let mut y = Tensor4::zeros(Dims::new(d.n, d.c, 1, 1), x.layout());
    global_avg_pool_into(x, &mut y).expect("freshly allocated GAP output is always valid");
    y
}

/// Global average pooling into a caller-provided `(n, c, 1, 1)` output in
/// `x`'s layout (every logical element overwritten).
pub fn global_avg_pool_into(x: &Tensor4, y: &mut Tensor4) -> Result<()> {
    let d = x.dims();
    let out_d = Dims::new(d.n, d.c, 1, 1);
    if y.dims() != out_d || y.layout() != x.layout() {
        return Err(Error::ShapeMismatch(format!(
            "gap output {} ({}) != expected {out_d} ({})",
            y.dims(),
            y.layout(),
            x.layout()
        )));
    }
    let inv = 1.0 / (d.h * d.w) as f32;
    for n in 0..d.n {
        for c in 0..d.c {
            let mut acc = 0.0;
            for h in 0..d.h {
                for w in 0..d.w {
                    acc += x.get(n, c, h, w);
                }
            }
            y.set(n, c, 0, 0, acc * inv);
        }
    }
    Ok(())
}

/// Fully connected layer: flattens `(c, h, w)` in **logical NCHW order**
/// (so results are layout-independent) and multiplies by
/// `weight[out_features][in_features]`. Output is `(n, out_features, 1, 1)`.
pub fn linear(x: &Tensor4, weight: &[f32], out_features: usize) -> Result<Tensor4> {
    let d = x.dims();
    let mut y = Tensor4::zeros(Dims::new(d.n, out_features, 1, 1), x.layout());
    linear_into(x, weight, out_features, &mut y)?;
    Ok(y)
}

/// Linear layer into a caller-provided `(n, out_features, 1, 1)` output in
/// `x`'s layout (every logical element overwritten).
pub fn linear_into(
    x: &Tensor4,
    weight: &[f32],
    out_features: usize,
    y: &mut Tensor4,
) -> Result<()> {
    let d = x.dims();
    let in_features = d.c * d.h * d.w;
    if weight.len() != in_features * out_features {
        return Err(Error::ShapeMismatch(format!(
            "linear weight {} != {in_features}x{out_features}",
            weight.len()
        )));
    }
    let out_d = Dims::new(d.n, out_features, 1, 1);
    if y.dims() != out_d || y.layout() != x.layout() {
        return Err(Error::ShapeMismatch(format!(
            "linear output {} ({}) != expected {out_d} ({})",
            y.dims(),
            y.layout(),
            x.layout()
        )));
    }
    // Flatten per image in logical order (cheap relative to conv layers).
    let mut feat = vec![0.0f32; in_features];
    for n in 0..d.n {
        let mut i = 0;
        for c in 0..d.c {
            for h in 0..d.h {
                for w in 0..d.w {
                    feat[i] = x.get(n, c, h, w);
                    i += 1;
                }
            }
        }
        for (o, row) in weight.chunks(in_features).enumerate() {
            y.set(n, o, 0, 0, crate::simd::dot(&feat, row));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives_all_layouts() {
        for layout in Layout::ALL {
            let x = Tensor4::random(Dims::new(3, 2, 4, 5), layout, 3);
            let y = relu(&x);
            for (n, c, h, w) in x.dims().iter() {
                assert_eq!(y.get(n, c, h, w), x.get(n, c, h, w).max(0.0), "{layout}");
            }
        }
    }

    #[test]
    fn max_pool_known_answer() {
        let x = Tensor4::from_logical(
            Dims::new(1, 1, 4, 4),
            Layout::Nchw,
            &[1., 2., 3., 4., 5., 6., 7., 8., 9., 10., 11., 12., 13., 14., 15., 16.],
        );
        let y = max_pool2d(&x, 2, 2).unwrap();
        assert_eq!(y.logical_vec(), vec![6., 8., 14., 16.]);
        // Overlapping 3x3 stride 1.
        let z = max_pool2d(&x, 3, 1).unwrap();
        assert_eq!(z.logical_vec(), vec![11., 12., 15., 16.]);
    }

    #[test]
    fn max_pool_rejects_oversized_window() {
        let x = Tensor4::zeros(Dims::new(1, 1, 3, 3), Layout::Nchw);
        assert!(max_pool2d(&x, 4, 1).is_err());
        assert!(max_pool2d(&x, 2, 0).is_err());
    }

    #[test]
    fn global_avg_pool_means() {
        let x = Tensor4::from_fn(Dims::new(2, 3, 2, 2), Layout::Nhwc, |n, c, h, w| {
            (n + c + h + w) as f32
        });
        let y = global_avg_pool(&x);
        assert_eq!(y.dims(), Dims::new(2, 3, 1, 1));
        // mean over h,w of (n+c+h+w) = n + c + mean(h+w) = n + c + 1
        for n in 0..2 {
            for c in 0..3 {
                assert!((y.get(n, c, 0, 0) - (n + c) as f32 - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn linear_is_layout_invariant() {
        let d = Dims::new(2, 3, 2, 2);
        let x = Tensor4::random(d, Layout::Nchw, 7);
        let w: Vec<f32> = (0..12 * 4).map(|i| (i as f32) * 0.1).collect();
        let base = linear(&x, &w, 4).unwrap();
        for layout in Layout::ALL {
            let y = linear(&x.to_layout(layout), &w, 4).unwrap();
            assert!(base.allclose(&y, 1e-5, 1e-6), "{layout}");
        }
        assert!(linear(&x, &w[1..], 4).is_err());
    }
}
