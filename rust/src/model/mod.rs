//! CNN model graph and runner.
//!
//! The paper's benchmark layers come from real networks (AlexNet, ZFNet,
//! VGG, Overfeat — the MEC suite); this module lets a downstream user
//! compose those layers into runnable models with any convolution
//! algorithm × layout per layer:
//!
//! * [`Op`] — Conv2d / ReLU / MaxPool2d / GlobalAvgPool / Linear;
//! * [`Model`] — a sequential graph with shape inference and a forward
//!   pass (batch taken from the input tensor);
//! * [`zoo`] — ready-made models: `mecnet` (the twelve Table I layers
//!   chained with pooling/activation), and `tinynet` (the CIFAR-scale CNN
//!   mirroring `python/compile/model.py`, used by the E2E train example to
//!   cross-check the PJRT path).

pub mod zoo;

mod ops;

pub use ops::{
    global_avg_pool, global_avg_pool_into, linear, linear_into, max_pool2d, max_pool2d_into,
    relu, relu_inplace,
};

use crate::conv::{AlgoKind, Conv2d, ConvParams};
use crate::error::{Error, Result};
use crate::tensor::{Dims, Layout, Tensor4};

/// One layer of a sequential CNN.
pub enum Op {
    /// 2-D convolution with a fixed filter.
    Conv(Conv2d),
    /// Elementwise `max(x, 0)`.
    Relu,
    /// Max pooling with square window `k` and stride `s` (valid padding).
    MaxPool {
        /// Pooling window edge.
        k: usize,
        /// Pooling stride.
        s: usize,
    },
    /// Average over all `(h, w)` positions, leaving `(n, c, 1, 1)`.
    GlobalAvgPool,
    /// Fully connected layer over the flattened `(c·h·w)` features.
    Linear {
        /// Weight matrix `[out_features][in_features]`, row-major.
        weight: Vec<f32>,
        /// Output feature count.
        out_features: usize,
    },
}

impl Op {
    /// Output dims for input dims `d`, or an error if incompatible.
    pub fn out_dims(&self, d: Dims) -> Result<Dims> {
        match self {
            Op::Conv(conv) => {
                let p = conv.params.with_batch(d.n);
                if d != p.input_dims() {
                    return Err(Error::ShapeMismatch(format!(
                        "conv expects {}, got {d}",
                        p.input_dims()
                    )));
                }
                Ok(p.output_dims())
            }
            Op::Relu => Ok(d),
            Op::MaxPool { k, s } => {
                if *k == 0 || *s == 0 || *k > d.h || *k > d.w {
                    return Err(Error::ShapeMismatch(format!("maxpool k={k} s={s} on {d}")));
                }
                Ok(Dims::new(d.n, d.c, (d.h - k) / s + 1, (d.w - k) / s + 1))
            }
            Op::GlobalAvgPool => Ok(Dims::new(d.n, d.c, 1, 1)),
            Op::Linear { weight, out_features } => {
                let in_features = d.c * d.h * d.w;
                if weight.len() != in_features * out_features {
                    return Err(Error::ShapeMismatch(format!(
                        "linear weight {} != {in_features}x{out_features}",
                        weight.len()
                    )));
                }
                Ok(Dims::new(d.n, *out_features, 1, 1))
            }
        }
    }
}

/// A sequential CNN. All intermediate activations use the model's layout.
pub struct Model {
    /// Human-readable model name.
    pub name: String,
    layout: Layout,
    ops: Vec<Op>,
    input_dims: Dims, // with n = reference batch (1)
}

impl Model {
    /// Start an empty model taking inputs of shape `(·, c, h, w)`.
    pub fn new(name: &str, layout: Layout, c: usize, h: usize, w: usize) -> Self {
        Model { name: name.into(), layout, ops: vec![], input_dims: Dims::new(1, c, h, w) }
    }

    /// The model's activation layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The model's layers, in execution order (read-only view for the
    /// inference engine's planner and executor).
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Mutable view of the layers — the hook the engine uses to apply a
    /// plan via [`Conv2d::reconfigure`] without rebuilding the model.
    pub fn ops_mut(&mut self) -> &mut [Op] {
        &mut self.ops
    }

    /// Reference input dims at batch 1 (`(1, c, h, w)`).
    pub fn input_dims(&self) -> Dims {
        self.input_dims
    }

    /// Geometries of the convolution layers, in order (batch 1).
    pub fn conv_params(&self) -> Vec<ConvParams> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                Op::Conv(c) => Some(c.params),
                _ => None,
            })
            .collect()
    }

    /// Output dims for a batch-`n` input.
    pub fn out_dims_for_batch(&self, n: usize) -> Result<Dims> {
        let mut d = Dims::new(n, self.input_dims.c, self.input_dims.h, self.input_dims.w);
        for op in &self.ops {
            d = op.out_dims(d)?;
        }
        Ok(d)
    }

    /// Current output dims for a batch-1 input (shape inference).
    pub fn out_dims(&self) -> Result<Dims> {
        let mut d = self.input_dims;
        for op in &self.ops {
            d = op.out_dims(d)?;
        }
        Ok(d)
    }

    /// Append a convolution (filter generated or supplied by the caller).
    pub fn conv(self, params: ConvParams, algo: AlgoKind, filter: &Tensor4) -> Result<Self> {
        self.push_conv(params, algo, filter, None)
    }

    /// Append a convolution with a per-output-channel bias. The bias is
    /// part of the conv op (applied by [`Conv2d::forward`]); the
    /// inference engine fuses it — together with a directly following
    /// [`Op::Relu`] — into the kernel's store epilogue.
    pub fn conv_bias(
        self,
        params: ConvParams,
        algo: AlgoKind,
        filter: &Tensor4,
        bias: &[f32],
    ) -> Result<Self> {
        self.push_conv(params, algo, filter, Some(bias))
    }

    fn push_conv(
        mut self,
        params: ConvParams,
        algo: AlgoKind,
        filter: &Tensor4,
        bias: Option<&[f32]>,
    ) -> Result<Self> {
        let d = self.out_dims()?;
        let p = params.with_batch(1);
        if p.input_dims() != d {
            return Err(Error::ShapeMismatch(format!(
                "conv input {} does not chain onto {}",
                p.input_dims(),
                d
            )));
        }
        let layer = match bias {
            Some(b) => Conv2d::with_bias(p, algo, self.layout, filter, b)?,
            None => Conv2d::new(p, algo, self.layout, filter)?,
        };
        self.ops.push(Op::Conv(layer));
        Ok(self)
    }

    /// Append a ReLU.
    pub fn relu(mut self) -> Self {
        self.ops.push(Op::Relu);
        self
    }

    /// Append a max-pool.
    pub fn max_pool(mut self, k: usize, s: usize) -> Result<Self> {
        self.ops.push(Op::MaxPool { k, s });
        self.out_dims()?; // validate chaining
        Ok(self)
    }

    /// Append a global average pool.
    pub fn global_avg_pool(mut self) -> Self {
        self.ops.push(Op::GlobalAvgPool);
        self
    }

    /// Append a fully connected layer with the given weight.
    pub fn linear(mut self, weight: Vec<f32>, out_features: usize) -> Result<Self> {
        self.ops.push(Op::Linear { weight, out_features });
        self.out_dims()?;
        Ok(self)
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the model has no layers.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Run the forward pass. The input may be in any layout; activations
    /// flow in the model layout and the result is returned in it. An
    /// input already in the model layout is *borrowed*, not deep-copied
    /// — the first layer reads the caller's tensor directly (ops never
    /// mutate their input; the in-place ReLU materializes its own copy
    /// first).
    pub fn forward(&self, input: &Tensor4) -> Result<Tensor4> {
        use std::borrow::Cow;
        let mut x: Cow<'_, Tensor4> = if input.layout() == self.layout {
            Cow::Borrowed(input)
        } else {
            Cow::Owned(input.to_layout(self.layout))
        };
        let expect = Dims::new(input.dims().n, self.input_dims.c, self.input_dims.h, self.input_dims.w);
        if x.dims() != expect {
            return Err(Error::ShapeMismatch(format!(
                "model {} expects input {expect}, got {}",
                self.name,
                x.dims()
            )));
        }
        for op in &self.ops {
            x = Cow::Owned(match op {
                Op::Conv(conv) => conv.forward(&x)?,
                Op::Relu => {
                    let mut y = x.into_owned();
                    relu_inplace(&mut y);
                    y
                }
                Op::MaxPool { k, s } => max_pool2d(&x, *k, *s)?,
                Op::GlobalAvgPool => global_avg_pool(&x),
                Op::Linear { weight, out_features } => linear(&x, weight, *out_features)?,
            });
        }
        Ok(x.into_owned())
    }

    /// Stable structural fingerprint (FNV-1a 64, hex): the model's name,
    /// activation layout, input shape, and the per-layer structure —
    /// convolution geometries (with bias presence), pooling windows and
    /// linear widths. Weight *values* are deliberately excluded: planning
    /// depends only on structure, and the fingerprint keys whole-graph
    /// plan-cache entries ([`crate::engine::graph::graph_key`]).
    pub fn fingerprint(&self) -> String {
        let mut text = format!(
            "{}|{}|{}x{}x{}",
            self.name, self.layout, self.input_dims.c, self.input_dims.h, self.input_dims.w
        );
        for op in &self.ops {
            match op {
                Op::Conv(conv) => {
                    let p = &conv.params;
                    text.push_str(&format!(
                        "|conv:{}x{}x{}->{}f{}x{}s{}x{}b{}",
                        p.c_in,
                        p.h_in,
                        p.w_in,
                        p.c_out,
                        p.h_f,
                        p.w_f,
                        p.stride_h,
                        p.stride_w,
                        u8::from(conv.bias().is_some())
                    ));
                    // Non-default geometry extends the component; dense
                    // layers keep their pre-generalization text, so old
                    // graph-cache entries stay valid for the models they
                    // described and can never alias a generalized one.
                    if !p.has_default_geometry() {
                        text.push_str(&format!(
                            "p{}x{}d{}x{}g{}",
                            p.pad_h, p.pad_w, p.dilation_h, p.dilation_w, p.groups
                        ));
                    }
                }
                Op::Relu => text.push_str("|relu"),
                Op::MaxPool { k, s } => text.push_str(&format!("|pool:{k}s{s}")),
                Op::GlobalAvgPool => text.push_str("|gap"),
                Op::Linear { weight, out_features } => {
                    text.push_str(&format!("|linear:{}x{}", weight.len(), out_features));
                }
            }
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("{h:016x}")
    }

    /// Total FLOPs of one forward pass at batch `n` (conv + linear only;
    /// elementwise ops are negligible and excluded, as in the paper).
    pub fn flops(&self, n: usize) -> Result<u64> {
        let mut d = Dims::new(n, self.input_dims.c, self.input_dims.h, self.input_dims.w);
        let mut total = 0u64;
        for op in &self.ops {
            if let Op::Conv(conv) = op {
                total += conv.params.with_batch(n).flops();
            }
            if let Op::Linear { out_features, .. } = op {
                total += 2 * (n * d.c * d.h * d.w * out_features) as u64;
            }
            d = op.out_dims(d)?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build_small(layout: Layout, algo: AlgoKind) -> Model {
        let p1 = ConvParams::builder().batch(1).channels(3, 4).input(12, 12).filter(3, 3).stride(1).build().unwrap();
        let f1 = Tensor4::random(p1.filter_dims(), Layout::Nchw, 1);
        let p2 = ConvParams::builder().batch(1).channels(4, 6).input(5, 5).filter(3, 3).stride(1).build().unwrap();
        let f2 = Tensor4::random(p2.filter_dims(), Layout::Nchw, 2);
        let head: Vec<f32> = (0..6 * 10).map(|i| (i as f32) * 0.01 - 0.3).collect();
        Model::new("small", layout, 3, 12, 12)
            .conv(p1, algo, &f1)
            .unwrap()
            .relu()
            .max_pool(2, 2)
            .unwrap()
            .conv(p2, algo, &f2)
            .unwrap()
            .relu()
            .global_avg_pool()
            .linear(head, 10)
            .unwrap()
    }

    #[test]
    fn shape_inference_chains() {
        let m = build_small(Layout::Nhwc, AlgoKind::Naive);
        assert_eq!(m.out_dims().unwrap(), Dims::new(1, 10, 1, 1));
        assert_eq!(m.len(), 7);
    }

    #[test]
    fn forward_runs_and_is_layout_invariant() {
        let x = Tensor4::random(Dims::new(3, 3, 12, 12), Layout::Nchw, 5);
        let base = build_small(Layout::Nchw, AlgoKind::Naive).forward(&x).unwrap();
        assert_eq!(base.dims(), Dims::new(3, 10, 1, 1));
        for layout in Layout::ALL {
            for algo in [AlgoKind::Direct, AlgoKind::Im2win, AlgoKind::Im2col] {
                let m = build_small(layout, algo);
                let y = m.forward(&x).unwrap();
                assert!(
                    base.allclose(&y, 1e-3, 1e-4),
                    "{layout} {algo}: diff {}",
                    base.max_abs_diff(&y)
                );
            }
        }
    }

    #[test]
    fn conv_bias_shifts_outputs_per_channel() {
        let p = ConvParams::builder().batch(1).channels(2, 3).input(6, 6).filter(3, 3).stride(1).build().unwrap();
        let f = Tensor4::random(p.filter_dims(), Layout::Nchw, 4);
        let x = Tensor4::random(p.input_dims(), Layout::Nchw, 5);
        let bias = [0.5f32, -1.0, 2.0];
        let plain = Model::new("p", Layout::Nchw, 2, 6, 6)
            .conv(p, AlgoKind::Naive, &f)
            .unwrap()
            .forward(&x)
            .unwrap();
        let biased = Model::new("b", Layout::Nchw, 2, 6, 6)
            .conv_bias(p, AlgoKind::Naive, &f, &bias)
            .unwrap()
            .forward(&x)
            .unwrap();
        for (n, c, h, w) in plain.dims().iter() {
            let d = biased.get(n, c, h, w) - plain.get(n, c, h, w);
            assert!((d - bias[c]).abs() < 1e-6, "c={c}: shift {d}");
        }
        // Wrong bias length is rejected at build time.
        assert!(Model::new("bad", Layout::Nchw, 2, 6, 6)
            .conv_bias(p, AlgoKind::Naive, &f, &bias[..2])
            .is_err());
    }

    #[test]
    fn mismatched_conv_chain_rejected() {
        let p1 = ConvParams::builder().batch(1).channels(3, 4).input(12, 12).filter(3, 3).stride(1).build().unwrap();
        let f1 = Tensor4::random(p1.filter_dims(), Layout::Nchw, 1);
        // Second conv expects 8 channels but gets 4.
        let p2 = ConvParams::builder().batch(1).channels(8, 6).input(10, 10).filter(3, 3).stride(1).build().unwrap();
        let f2 = Tensor4::random(p2.filter_dims(), Layout::Nchw, 2);
        let err = Model::new("bad", Layout::Nchw, 3, 12, 12)
            .conv(p1, AlgoKind::Naive, &f1)
            .unwrap()
            .conv(p2, AlgoKind::Naive, &f2);
        assert!(err.is_err());
    }

    #[test]
    fn flops_counts_conv_and_linear() {
        let m = build_small(Layout::Nchw, AlgoKind::Naive);
        let f = m.flops(2).unwrap();
        let p1 = ConvParams::builder().batch(2).channels(3, 4).input(12, 12).filter(3, 3).stride(1).build().unwrap();
        let p2 = ConvParams::builder().batch(2).channels(4, 6).input(5, 5).filter(3, 3).stride(1).build().unwrap();
        assert_eq!(f, p1.flops() + p2.flops() + 2 * (2 * 6 * 10) as u64);
    }
}
