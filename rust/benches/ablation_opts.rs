//! A1/A2 ablations (DESIGN.md §4): the §III-D optimization ladder and the
//! `W_{o,b}` blocking-size sensitivity on representative layers.
//!
//! ```bash
//! cargo bench --bench ablation_opts -- --scale ci --layers conv5,conv9
//! ```

mod common;

use im2win::autotune::tune_w_block;
use im2win::bench_harness::fmt_time;
use im2win::conv::AlgoKind;
use im2win::coordinator::{experiments, layers, write_csv};
use im2win::tensor::Layout;

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("ablation_opts: test mode, skipping measurement");
        return;
    }
    let selected = if cfg.layers.is_empty() {
        vec!["conv5".to_string(), "conv9".to_string()]
    } else {
        cfg.layers.clone()
    };

    // A1 — optimization ladder per layout.
    let mut all = Vec::new();
    for name in &selected {
        let layer = layers::by_name(name).expect("unknown layer");
        for layout in [Layout::Nhwc, Layout::Nchw] {
            println!("\nA1 optimization ladder — {name} ({layout}):");
            let records = experiments::ablation(layer, layout, cfg.scale).expect("ablation failed");
            let naive = records[0].best_s;
            for r in &records {
                println!(
                    "  {:<24} {:>12}  {:>8.2} GFLOPS  ({:>5.1}x vs naive)",
                    r.algo,
                    fmt_time(r.best_s),
                    r.gflops(),
                    naive / r.best_s
                );
            }
            all.extend(records);
        }
    }
    write_csv(format!("reports/ablation_{}.csv", cfg.scale.name()), &all).unwrap();

    // A2 — W_o,b sensitivity sweep.
    for name in &selected {
        let layer = layers::by_name(name).expect("unknown layer");
        let p = experiments::layer_params(layer, cfg.scale);
        for algo in [AlgoKind::Im2win, AlgoKind::Direct] {
            let report = tune_w_block(algo, Layout::Nhwc, &p, cfg.scale.repeats())
                .expect("tune failed");
            let best = report.best();
            println!(
                "\nA2 W_o,b sweep — {algo} NHWC {name}: best W_o,b = {} ({:.2}x spread)",
                best.w_block,
                report.sensitivity()
            );
            for pt in &report.points {
                println!(
                    "  W_o,b = {:<2} {:>12}  {:>8.2} GFLOPS",
                    pt.w_block,
                    fmt_time(pt.result.best_s),
                    p.flops() as f64 / pt.result.best_s / 1e9
                );
            }
        }
    }
}
