//! Layout-conversion microbench: effective bandwidth of every ordered
//! layout pair (4 layouts → 12 ordered pairs) across the Table I
//! geometries. These are the numbers the graph planner's edge costs come
//! from: `calibrate::measure_convert` records the per-pair mean into the
//! `CalibrationProfile` and `Planner::convert_cost` prices a conversion
//! as `2 × destination bytes / bandwidth` — the same read-plus-write
//! convention this bench reports, so a printed GB/s cell and the
//! planner's cost for that pair round-trip exactly.
//!
//! ```bash
//! cargo bench --bench layout_convert -- --scale ci
//! cargo bench --bench layout_convert -- --layers conv5,conv9 --json convert.json
//! ```
//!
//! `--json PATH` writes the per-pair matrix plus the fitted profile table
//! as a JSON document for the CI perf-trajectory artifact.

mod common;

use im2win::config::json::Json;
use im2win::coordinator::layers;
use im2win::engine::calibrate::{self, CalibrationProfile};
use im2win::prelude::*;
use im2win::tensor::transform_into;

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("layout_convert: test mode, skipping measurement");
        return;
    }
    let scale = cfg.scale;
    let repeats = scale.repeats().max(3);
    let selected = layers::select(&cfg.layers);
    let geoms: Vec<(&str, Dims)> = selected
        .iter()
        .map(|l| {
            (l.name, l.scaled_params(scale.batch(), scale.spatial_div()).input_dims())
        })
        .collect();

    println!(
        "layout_convert — {} geometries, scale={}, {} repeats, {} threads",
        geoms.len(),
        scale.name(),
        repeats,
        im2win::parallel::global().threads()
    );
    print!("{:>14}", "pair \\ GB/s");
    for (name, _) in &geoms {
        print!(" {name:>8}");
    }
    println!("     mean");

    // Per-pair × per-geometry matrix: pre-allocated destination, so the
    // timing sees only the data movement; bandwidth counts the read and
    // the write (2 × destination storage bytes / best time).
    let mut pair_rows: Vec<(String, Json)> = Vec::new();
    for from in Layout::ALL {
        for to in Layout::ALL {
            if from == to {
                continue;
            }
            print!("{:>6} -> {:<5}", from.name(), to.name());
            let mut cells: Vec<(String, Json)> = Vec::new();
            let (mut sum, mut n) = (0.0, 0usize);
            for &(name, dims) in &geoms {
                let src = Tensor4::random(dims, from, 0x5EED);
                let mut dst = Tensor4::zeros(dims, to);
                let bytes = dst.storage_bytes() as f64;
                let r = im2win::bench_harness::measure(repeats, || {
                    transform_into(&src, &mut dst);
                });
                let gbps = if r.best_s > 0.0 { 2.0 * bytes / r.best_s / 1e9 } else { 0.0 };
                print!(" {gbps:>8.2}");
                cells.push((name.to_string(), Json::Number(gbps)));
                sum += gbps;
                n += 1;
            }
            let mean = if n > 0 { sum / n as f64 } else { 0.0 };
            println!(" {mean:>8.2}");
            cells.push(("mean_gbps".into(), Json::Number(mean)));
            pair_rows.push((
                calibrate::convert_key(from, to),
                Json::Object(cells),
            ));
        }
    }

    // Fit the same measurement into a calibration profile — this is
    // exactly what `im2win calibrate --run` does, and what the graph
    // planner reads back through `convert_bandwidth`.
    let mut profile =
        CalibrationProfile::new(0.0, im2win::parallel::global().threads());
    let dims: Vec<Dims> = geoms.iter().map(|&(_, d)| d).collect();
    let pairs = calibrate::measure_convert(&mut profile, &dims, repeats);
    println!("\nfitted into CalibrationProfile ({pairs} pairs):");
    for (key, stat) in profile.converts() {
        println!("  {key:<16} {:>8.2} GB/s  ({} geometries)", stat.gbps, stat.samples);
    }

    if let Some(path) = common::json_path() {
        let fitted: Vec<(String, Json)> = profile
            .converts()
            .map(|(k, s)| (k.to_string(), Json::Number(s.gbps)))
            .collect();
        let doc = Json::object(vec![
            ("bench", Json::from("layout_convert")),
            ("scale", Json::from(scale.name())),
            (
                "threads",
                Json::Number(im2win::parallel::global().threads() as f64),
            ),
            ("geometries", Json::Number(geoms.len() as f64)),
            ("pairs_gbps", Json::Object(pair_rows)),
            ("fitted_gbps", Json::Object(fitted)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("writing the --json artifact");
        println!("\nwrote {path}");
    }
}
