//! GEMM substrate microbenchmark: the im2col baseline is only as honest as
//! its SGEMM, so this bench reports the blocked kernel's GFLOPS against
//! the single-core Eq. 4 peak on square and conv-shaped problems.
//!
//! ```bash
//! cargo bench --bench gemm_micro
//! ```

mod common;

use im2win::bench_harness::{fmt_time, measure};
use im2win::gemm::sgemm;
use im2win::roofline::MachineSpec;

fn bench_case(m: usize, n: usize, k: usize, repeats: usize, peak1: f64) {
    let a = vec![1.0f32; m * k];
    let b = vec![0.5f32; k * n];
    let mut c = vec![0.0f32; m * n];
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let r = measure(repeats, || {
        sgemm(m, n, k, &a, k, &b, n, &mut c, n);
    });
    println!(
        "  {m:>5} x {n:>5} x {k:>5}  {:>12}  {:>7.2} GFLOPS  ({:>4.0}% of 1-core peak)",
        fmt_time(r.best_s),
        flops / r.best_s / 1e9,
        100.0 * flops / r.best_s / peak1
    );
}

fn main() {
    if common::is_test_mode() {
        println!("gemm_micro: test mode, skipping measurement");
        return;
    }
    let cfg = common::config_from_args();
    let peak1 = MachineSpec::detect().peak_flops_single_core();
    println!(
        "blocked SGEMM vs single-core Eq.4 peak ({:.0} GFLOPS), scale={}\n",
        peak1 / 1e9,
        cfg.scale.name()
    );
    println!("square:");
    for s in [64, 128, 256, 512] {
        bench_case(s, s, s, cfg.scale.repeats(), peak1);
    }
    println!("conv-shaped (im2col panels of Table I at batch 1):");
    // conv9: M = Ho*Wo = 2916, N = Co = 64, K = Ci*Hf*Wf = 576
    bench_case(2916, 64, 576, cfg.scale.repeats(), peak1);
    // conv5: M = 400, N = 256, K = 2400
    bench_case(400, 256, 2400, cfg.scale.repeats(), peak1);
    // conv12: M = 25, N = 512, K = 4608
    bench_case(25, 512, 4608, cfg.scale.repeats(), peak1);
}
