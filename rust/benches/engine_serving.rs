//! Serving-path benchmark: sustained inferences/sec through the planned
//! engine at batch sizes 1 / 8 / 32, plus the micro-batching server's
//! end-to-end throughput. Future PRs touching the engine, workspace or
//! server compare against these numbers to catch serving regressions.
//!
//! ```bash
//! cargo bench --bench engine_serving -- --scale ci
//! cargo bench --bench engine_serving -- --threads 8
//! ```

mod common;

use im2win::bench_harness::{fmt_time, measure_throughput};
use im2win::config::Scale;
use im2win::conv::AlgoKind;
use im2win::engine::{Engine, PlanCache, Planner, Server};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;

const BATCHES: [usize; 3] = [1, 8, 32];

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("engine_serving: test mode, skipping measurement");
        return;
    }
    let iters = match cfg.scale {
        Scale::Full => 30,
        Scale::Ci => 8,
        Scale::Smoke => 2,
    };

    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 7).expect("tinynet builds");
    let mut cache = PlanCache::in_memory();
    let mut engine =
        Engine::plan(model, &Planner::new(), &mut cache).expect("engine planning succeeds");
    println!(
        "engine_serving — tinynet, scale={}, {} iters/batch, {} threads",
        cfg.scale.name(),
        iters,
        im2win::parallel::global().threads()
    );
    for (i, plan) in engine.plans().iter().enumerate() {
        println!("  layer {i}: {} {} W_o,b={}", plan.algo.name(), plan.layout, plan.w_block);
    }

    // Direct engine forwards at fixed batch sizes (the serving hot path,
    // no queueing): inferences/sec must scale with batch.
    println!("\nengine.forward_into throughput:");
    for batch in BATCHES {
        let x = Tensor4::random(Dims::new(batch, 3, 32, 32), Layout::Nchw, batch as u64);
        let mut out = Tensor4::zeros(
            engine.output_dims(batch).expect("output dims"),
            Layout::Nchw,
        );
        let r = measure_throughput(batch, iters, || {
            engine.forward_into(&x, &mut out).expect("forward succeeds");
        });
        println!(
            "  batch {batch:>3}: {:>8.1} inf/s   ({} per batched call)",
            r.inf_per_s(),
            fmt_time(r.latency_s())
        );
    }

    // End-to-end micro-batching server: queue + coalesce + scatter.
    let requests = 32 * iters;
    let server = Server::start(engine, 8);
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            server.submit(Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, i as u64))
        })
        .collect();
    for rx in &receivers {
        rx.recv().expect("server alive").expect("inference succeeds");
    }
    let report = server.shutdown();
    println!("\nserver micro-batching ({requests} single-image requests, max batch 8):");
    println!(
        "  {} batches, avg batch {:.2}, busy {}, {:.1} inf/s, {} warm allocs",
        report.batches,
        report.avg_batch(),
        fmt_time(report.busy_s),
        report.throughput(),
        report.warm_misses
    );
}
