//! Serving-path benchmark: sustained inferences/sec through the planned
//! engine at batch sizes 1 / 8 / 32, the prepacked + fused bias/ReLU
//! epilogue path on the biased tinynet, the micro-batching server's
//! end-to-end throughput, the sharded deadline-batching front at 2
//! shards, the async non-blocking front under an open-loop arrival
//! generator (offered load ~1.5× the measured sync throughput, so the
//! rings visibly backpressure), the graph planner's mixed-layout
//! mixnet execution against the greedy per-layer plan (the global DP
//! must not lose to greedy), the depthwise-separable mobilenet_v1
//! serving path (with the planner-selected depthwise layer count as a
//! CI invariant), and the widened algorithm menu — indirect convolution
//! and Winograd F(2×2, 3×3) prepacked throughput on a Table I 3×3
//! layer, with the planner's per-family selection counts over the
//! Table I 3×3/stride-1 sweep as CI invariants, and the
//! reduced-precision serving path — the biased tinynet forced to the
//! f16 and int8 tiers, with the loosened-budget planner's sub-f32
//! selection counts over the full Table I as CI invariants. Future PRs touching the
//! engine, workspace, server or dispatcher compare against these
//! numbers to catch serving regressions.
//!
//! ```bash
//! cargo bench --bench engine_serving -- --scale ci
//! cargo bench --bench engine_serving -- --threads 8
//! cargo bench --bench engine_serving -- --scale smoke --json serving.json
//! ```
//!
//! `--json PATH` writes the headline numbers as a JSON document — the CI
//! bench-smoke job uploads it as the perf-trajectory artifact.

mod common;

use im2win::bench_harness::{fmt_time, measure_throughput};
use im2win::config::json::Json;
use im2win::config::Scale;
use im2win::conv::indirect::IndirectConv;
use im2win::conv::precision::{F16_TOLERANCE, INT8_TOLERANCE};
use im2win::conv::winograd::{WinogradConv, WINOGRAD_TOLERANCE};
use im2win::conv::{AlgoKind, ConvAlgorithm, ConvParams, Precision};
use im2win::coordinator::layers;
use im2win::engine::{
    AsyncConfig, AsyncServer, Engine, PlanCache, Planner, Server, ShardConfig, ShardedServer,
    Shed, TrySubmitError, Workspace,
};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

const BATCHES: [usize; 3] = [1, 8, 32];
const SHARDS: usize = 2;

fn tinynet_engine(planner: &Planner) -> Engine {
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 7).expect("tinynet builds");
    let mut cache = PlanCache::in_memory();
    Engine::plan(model, planner, &mut cache).expect("engine planning succeeds")
}

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("engine_serving: test mode, skipping measurement");
        return;
    }
    let iters = match cfg.scale {
        Scale::Full => 30,
        Scale::Ci => 8,
        Scale::Smoke => 2,
    };

    let mut engine = tinynet_engine(&Planner::new());
    println!(
        "engine_serving — tinynet, scale={}, {} iters/batch, {} threads",
        cfg.scale.name(),
        iters,
        im2win::parallel::global().threads()
    );
    for (i, plan) in engine.plans().iter().enumerate() {
        println!("  layer {i}: {} {} W_o,b={}", plan.algo.name(), plan.layout, plan.w_block);
    }

    // Direct engine forwards at fixed batch sizes (the serving hot path,
    // no queueing): inferences/sec must scale with batch.
    let mut engine_rows: Vec<(String, Json)> = Vec::new();
    println!("\nengine.forward_into throughput:");
    for batch in BATCHES {
        let x = Tensor4::random(Dims::new(batch, 3, 32, 32), Layout::Nchw, batch as u64);
        let mut out = Tensor4::zeros(
            engine.output_dims(batch).expect("output dims"),
            Layout::Nchw,
        );
        let r = measure_throughput(batch, iters, || {
            engine.forward_into(&x, &mut out).expect("forward succeeds");
        });
        println!(
            "  batch {batch:>3}: {:>8.1} inf/s   ({} per batched call)",
            r.inf_per_s(),
            fmt_time(r.latency_s())
        );
        engine_rows.push((format!("batch_{batch}"), Json::Number(r.inf_per_s())));
    }

    // Prepacked + fused epilogue path: the biased tinynet routes every
    // conv's bias and following ReLU through the kernels' store
    // epilogues, with filters packed once at plan time. This is the
    // serving hot path the check_bench gate tracks for fusion
    // regressions.
    let model = zoo::tinynet_biased(Layout::Nchw, AlgoKind::Naive, 7).expect("biased tinynet");
    let mut cache = PlanCache::in_memory();
    let mut fused_engine =
        Engine::plan(model, &Planner::new(), &mut cache).expect("engine planning succeeds");
    let mut fused_rows: Vec<(String, Json)> = Vec::new();
    println!(
        "\nprepacked+fused engine.forward_into throughput (biased tinynet, {} fused ReLUs):",
        fused_engine.fused_relu_count()
    );
    for batch in BATCHES {
        let x = Tensor4::random(Dims::new(batch, 3, 32, 32), Layout::Nchw, batch as u64);
        let mut out = Tensor4::zeros(
            fused_engine.output_dims(batch).expect("output dims"),
            Layout::Nchw,
        );
        let r = measure_throughput(batch, iters, || {
            fused_engine.forward_into(&x, &mut out).expect("fused forward succeeds");
        });
        println!(
            "  batch {batch:>3}: {:>8.1} inf/s   ({} per batched call)",
            r.inf_per_s(),
            fmt_time(r.latency_s())
        );
        fused_rows.push((format!("batch_{batch}"), Json::Number(r.inf_per_s())));
    }

    // End-to-end micro-batching server: queue + coalesce + scatter.
    let requests = 32 * iters;
    let server = Server::start(engine, 8);
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            server.submit(Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, i as u64))
        })
        .collect();
    for rx in &receivers {
        rx.recv().expect("server alive").expect("inference succeeds");
    }
    let report = server.shutdown();
    println!("\nserver micro-batching ({requests} single-image requests, max batch 8):");
    println!(
        "  {} batches, avg batch {:.2}, busy {}, {:.1} inf/s, p50 {}, p99 {}, {} warm allocs",
        report.batches,
        report.avg_batch(),
        fmt_time(report.busy_s),
        report.throughput(),
        fmt_time(report.p50_latency_s),
        fmt_time(report.p99_latency_s),
        report.warm_misses
    );

    // Sharded front: least-loaded dispatch over SHARDS engines with a
    // 200 µs batching window, plans keyed per shard.
    let shard_planner = Planner::new().for_shards(SHARDS);
    let engines: Vec<Engine> = (0..SHARDS).map(|_| tinynet_engine(&shard_planner)).collect();
    let sharded = ShardedServer::start(
        engines,
        ShardConfig {
            max_batch: 8,
            deadline: Duration::from_micros(200),
            threads_per_shard: shard_planner.threads,
            ..ShardConfig::default()
        },
    );
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            sharded.submit(Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, i as u64))
        })
        .collect();
    for rx in &receivers {
        rx.recv().expect("sharded server alive").expect("inference succeeds");
    }
    let sharded_report = sharded.shutdown();
    println!(
        "\nsharded front ({requests} requests, {SHARDS} shards, max batch 8, 200 us window):"
    );
    println!(
        "  {} batches ({} deadline flushes), {:.1} inf/s, worst p99 {}",
        sharded_report.batches(),
        sharded_report.deadline_flushes(),
        sharded_report.throughput(),
        fmt_time(sharded_report.p99_latency_s())
    );
    for (i, s) in sharded_report.shards.iter().enumerate() {
        println!(
            "  shard {i}: served {:>5}, avg batch {:.2}, occ {:.1}%, p99 {}",
            s.served,
            s.avg_batch(),
            s.occupancy() * 100.0,
            fmt_time(s.p99_latency_s)
        );
    }

    // Async non-blocking front: an open-loop arrival generator offers
    // requests at ~1.5x the sync server's measured throughput, so the
    // bounded rings exercise real backpressure (QueueFull is counted,
    // not retried — open loop means arrivals do not wait on the server).
    let offered = (report.throughput() * 1.5).max(200.0);
    let shard_planner = Planner::new().for_shards(SHARDS);
    let engines: Vec<Engine> = (0..SHARDS).map(|_| tinynet_engine(&shard_planner)).collect();
    let async_server = AsyncServer::start(
        engines,
        ShardConfig {
            max_batch: 8,
            deadline: Duration::from_micros(200),
            threads_per_shard: shard_planner.threads,
            ..ShardConfig::default()
        },
        AsyncConfig { queue_depth: 64, shed: Shed::Reject, ..AsyncConfig::default() },
    );
    let client = async_server.client();
    let start = Instant::now();
    let mut pending: VecDeque<_> = VecDeque::with_capacity(requests);
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    for k in 0..requests {
        let due = start + Duration::from_secs_f64(k as f64 / offered);
        while Instant::now() < due {
            std::hint::spin_loop();
        }
        let img = Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, k as u64);
        match client.try_submit(img) {
            Ok(t) => {
                admitted += 1;
                pending.push_back(t);
            }
            Err(TrySubmitError::QueueFull(_) | TrySubmitError::Overloaded(_)) => rejected += 1,
            Err(TrySubmitError::Closed(_)) => break,
        }
        // Opportunistically consume completed tickets so outstanding
        // handles stay bounded: slot_allocs should measure the server's
        // freelist, not this harness hoarding every ticket to the end.
        while let Some(mut t) = pending.pop_front() {
            match t.try_wait() {
                Some(r) => {
                    r.expect("async inference succeeds");
                }
                None => {
                    pending.push_front(t);
                    break;
                }
            }
        }
    }
    for t in pending {
        t.wait().expect("async inference succeeds");
    }
    let async_report = async_server.shutdown();
    println!(
        "\nasync front ({requests} offered at {offered:.0}/s, {SHARDS} shards, \
         depth 64, shed=reject):"
    );
    println!(
        "  admitted {admitted} / rejected {rejected}, {} batches, {:.1} inf/s, \
         queue p99 {}, done p99 {}, slot allocs {}",
        async_report.sharded.batches(),
        async_report.sharded.throughput(),
        fmt_time(async_report.sharded.p99_queue_s()),
        fmt_time(async_report.sharded.p99_latency_s()),
        async_report.slot_allocs,
    );

    // Graph-planned vs greedy mixed-layout execution: on mixnet the
    // greedy per-layer planner keeps the stem in the incoming NCHW
    // (each layer alone cannot pay for a conversion) while the exact
    // DP converts once and runs both stem convs in CHWN8 — the global
    // optimum. The planner is pinned to threads=4 / batch=8 so the
    // cost-model regime (and therefore the plans under test) is stable
    // across runner core counts.
    let graph_planner = Planner { threads: 4, batch: 8, ..Planner::new() };
    let mixnet = || zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 42).expect("mixnet builds");
    let mut cache = PlanCache::in_memory();
    let mut greedy_engine =
        Engine::plan(mixnet(), &graph_planner, &mut cache).expect("greedy planning succeeds");
    let mut cache = PlanCache::in_memory();
    let mut graph_engine = Engine::plan_graph(mixnet(), &graph_planner, &mut cache)
        .expect("graph planning succeeds");
    let gbatch = 8;
    let gx = Tensor4::random(Dims::new(gbatch, 3, 40, 40), Layout::Nchw, 11);
    let mut gout = Tensor4::zeros(
        graph_engine.output_dims(gbatch).expect("output dims"),
        Layout::Nchw,
    );
    let greedy_r = measure_throughput(gbatch, iters, || {
        greedy_engine.forward_into(&gx, &mut gout).expect("greedy forward succeeds");
    });
    let graph_r = measure_throughput(gbatch, iters, || {
        graph_engine.forward_into(&gx, &mut gout).expect("graph forward succeeds");
    });
    let gplan = graph_engine.graph_plan().expect("graph engine carries its plan");
    println!(
        "\ngraph planner vs greedy (mixnet, batch {gbatch}, {} layouts, {} conversions):",
        gplan.distinct_layouts(),
        gplan.conversions.len()
    );
    println!("  greedy: {:>8.1} inf/s", greedy_r.inf_per_s());
    println!(
        "  graph:  {:>8.1} inf/s   ({:.2}x)",
        graph_r.inf_per_s(),
        graph_r.inf_per_s() / greedy_r.inf_per_s().max(1e-9)
    );

    // MobileNet-class depthwise-separable serving: mobilenet_v1's five
    // depthwise layers route through the dedicated depthwise kernels
    // whenever the planner picks them. The emitted depthwise_layers
    // count doubles as a CI invariant — if the planner ever stops
    // selecting the specialist for depthwise geometry, the row drops to
    // zero and the gate fails. Pinned to threads=4 / batch=8 like the
    // graph section so the plans under test are runner-independent.
    let mob_planner = Planner { threads: 4, batch: 8, ..Planner::new() };
    let model = zoo::mobilenet_v1(Layout::Nchw, AlgoKind::Naive, 42).expect("mobilenet builds");
    let mut cache = PlanCache::in_memory();
    let mut mob_engine =
        Engine::plan(model, &mob_planner, &mut cache).expect("mobilenet planning succeeds");
    let dw_layers = mob_engine
        .plans()
        .iter()
        .filter(|pl| pl.algo == AlgoKind::Depthwise)
        .count();
    let mbatch = 8;
    let mx = Tensor4::random(Dims::new(mbatch, 3, 32, 32), Layout::Nchw, 13);
    let mut mout = Tensor4::zeros(
        mob_engine.output_dims(mbatch).expect("output dims"),
        Layout::Nchw,
    );
    let mob_r = measure_throughput(mbatch, iters, || {
        mob_engine.forward_into(&mx, &mut mout).expect("mobilenet forward succeeds");
    });
    println!(
        "\nmobilenet_v1 (batch {mbatch}, {dw_layers} of {} convs planned depthwise):",
        mob_engine.plans().len()
    );
    println!(
        "  {:>8.1} inf/s   ({} per batched call)",
        mob_r.inf_per_s(),
        fmt_time(mob_r.latency_s())
    );

    // Widened algorithm menu: indirect convolution and Winograd
    // F(2×2, 3×3) on the prepacked serving path, at a conv10-class 3×3
    // layer. The planner-selection sweep runs the analytic planner
    // pinned to threads=4 / batch=8 (runner-independent, like the graph
    // and mobilenet sections) over every Table I 3×3/stride-1 layer:
    // under the default tolerance budget at least one layer must route
    // to indirect, and once the budget admits WINOGRAD_TOLERANCE at
    // least one must route to Winograd. Both counts are CI invariants —
    // if either family drops out of the planner's menu, its
    // selected_layers row hits zero and the gate fails.
    let menu_planner = Planner { threads: 4, batch: 8, ..Planner::new() };
    let loose_planner = Planner { tolerance: WINOGRAD_TOLERANCE, ..menu_planner.clone() };
    let mut indirect_layers = 0usize;
    let mut winograd_layers = 0usize;
    let mut sweep_names: Vec<&str> = Vec::new();
    for l in layers::TABLE1.iter().filter(|l| l.k == 3 && l.s == 1) {
        sweep_names.push(l.name);
        let p = l.params(8);
        if menu_planner.plan_conv(&p, Layout::Nhwc).algo == AlgoKind::Indirect {
            indirect_layers += 1;
        }
        if loose_planner.plan_conv(&p, Layout::Nhwc).algo == AlgoKind::Winograd {
            winograd_layers += 1;
        }
    }
    let bench_p: ConvParams = layers::by_name("conv10")
        .expect("Table I has conv10")
        .scaled_params(4, 2);
    let mlayout = Layout::Nhwc;
    let minput = Tensor4::random(bench_p.input_dims(), mlayout, 17);
    let mfilter = Tensor4::random(bench_p.filter_dims(), mlayout, 18);
    let mut mlout = Tensor4::zeros(bench_p.output_dims(), mlayout);
    let mut mws = Workspace::new();
    let ind = IndirectConv::new();
    let ind_art = ind.prepare(&mfilter, &bench_p, mlayout).expect("indirect prepare");
    let ind_r = measure_throughput(bench_p.n, iters, || {
        ind.run_prepacked(&minput, &ind_art, &bench_p, &mut mlout, &mut mws, Epilogue::None)
            .expect("indirect runs");
    });
    let wino = WinogradConv::new();
    let wino_art = wino.prepare(&mfilter, &bench_p, mlayout).expect("winograd prepare");
    let wino_r = measure_throughput(bench_p.n, iters, || {
        wino.run_prepacked(&minput, &wino_art, &bench_p, &mut mlout, &mut mws, Epilogue::None)
            .expect("winograd runs");
    });
    println!(
        "\nalgorithm menu (conv10/2 prepacked, {mlayout}; sweep over {}):",
        sweep_names.join(",")
    );
    println!(
        "  indirect: {:>8.1} inf/s   ({} of {} sweep layers planner-selected)",
        ind_r.inf_per_s(),
        indirect_layers,
        sweep_names.len()
    );
    println!(
        "  winograd: {:>8.1} inf/s   ({} of {} sweep layers planner-selected at tol {WINOGRAD_TOLERANCE:.0e})",
        wino_r.inf_per_s(),
        winograd_layers,
        sweep_names.len()
    );

    // Reduced-precision serving: the biased tinynet forced to each
    // sub-f32 tier (filters packed once through the tier's grid at plan
    // time, activations converted in the lowering step, f32
    // accumulation; int8 folds its dequant scales into the fused
    // epilogue). The selection sweep runs the analytic planner pinned
    // to threads=4 / batch=8 over the full Table I at each tier's
    // admission budget: the selected_layers rows are CI invariants —
    // if a loosened tolerance ever stops buying a sub-f32 plan on any
    // Table I layer, the row hits zero and the gate fails.
    let f16_budget = Planner { threads: 4, batch: 8, tolerance: F16_TOLERANCE, ..Planner::new() };
    let int8_budget = Planner { tolerance: INT8_TOLERANCE, ..f16_budget.clone() };
    let mut f16_selected = 0usize;
    let mut int8_selected = 0usize;
    for l in layers::TABLE1.iter() {
        let p = l.params(8);
        if f16_budget.plan_conv(&p, Layout::Nhwc).precision.is_reduced() {
            f16_selected += 1;
        }
        if int8_budget.plan_conv(&p, Layout::Nhwc).precision == Precision::Int8 {
            int8_selected += 1;
        }
    }
    println!("\nreduced-precision serving (biased tinynet forced per tier, batch 8):");
    let mut precision_rows: Vec<(&'static str, f64, usize)> = Vec::new();
    for (prec, selected) in
        [(Precision::F16AccF32, f16_selected), (Precision::Int8, int8_selected)]
    {
        let planner =
            Planner { precision: Some(prec), tolerance: prec.min_tolerance(), ..Planner::new() };
        let model =
            zoo::tinynet_biased(Layout::Nchw, AlgoKind::Naive, 7).expect("biased tinynet");
        let mut cache = PlanCache::in_memory();
        let mut eng =
            Engine::plan(model, &planner, &mut cache).expect("reduced-tier planning succeeds");
        let batch = 8;
        let x = Tensor4::random(Dims::new(batch, 3, 32, 32), Layout::Nchw, batch as u64);
        let mut out =
            Tensor4::zeros(eng.output_dims(batch).expect("output dims"), Layout::Nchw);
        let r = measure_throughput(batch, iters, || {
            eng.forward_into(&x, &mut out).expect("reduced-tier forward succeeds");
        });
        println!(
            "  {:<4}: {:>8.1} inf/s   ({} of {} Table I layers planner-selected at tol {:.0e})",
            prec.name(),
            r.inf_per_s(),
            selected,
            layers::TABLE1.len(),
            prec.min_tolerance(),
        );
        precision_rows.push((prec.name(), r.inf_per_s(), selected));
    }

    // Machine-readable artifact for the CI perf trajectory.
    if let Some(path) = common::json_path() {
        let doc = Json::object(vec![
            ("bench", Json::from("engine_serving")),
            ("scale", Json::from(cfg.scale.name())),
            (
                "threads",
                Json::Number(im2win::parallel::global().threads() as f64),
            ),
            ("engine_inf_per_s", Json::Object(engine_rows)),
            ("prepacked", Json::Object(fused_rows)),
            (
                "graph",
                Json::object(vec![
                    ("greedy_inf_per_s", Json::Number(greedy_r.inf_per_s())),
                    ("graph_inf_per_s", Json::Number(graph_r.inf_per_s())),
                ]),
            ),
            (
                "mobilenet",
                Json::object(vec![
                    ("batch_8", Json::Number(mob_r.inf_per_s())),
                    ("depthwise_layers", Json::Number(dw_layers as f64)),
                ]),
            ),
            (
                "indirect",
                Json::object(vec![
                    ("inf_per_s", Json::Number(ind_r.inf_per_s())),
                    ("selected_layers", Json::Number(indirect_layers as f64)),
                ]),
            ),
            (
                "winograd",
                Json::object(vec![
                    ("inf_per_s", Json::Number(wino_r.inf_per_s())),
                    ("selected_layers", Json::Number(winograd_layers as f64)),
                ]),
            ),
            (
                "f16",
                Json::object(vec![
                    ("inf_per_s", Json::Number(precision_rows[0].1)),
                    ("selected_layers", Json::Number(precision_rows[0].2 as f64)),
                ]),
            ),
            (
                "int8",
                Json::object(vec![
                    ("inf_per_s", Json::Number(precision_rows[1].1)),
                    ("selected_layers", Json::Number(precision_rows[1].2 as f64)),
                ]),
            ),
            (
                "server",
                Json::object(vec![
                    ("requests", Json::Number(requests as f64)),
                    ("inf_per_s", Json::Number(report.throughput())),
                    ("avg_batch", Json::Number(report.avg_batch())),
                    ("p50_latency_s", Json::Number(report.p50_latency_s)),
                    ("p99_latency_s", Json::Number(report.p99_latency_s)),
                    ("warm_misses", Json::Number(report.warm_misses as f64)),
                ]),
            ),
            (
                "sharded",
                Json::object(vec![
                    ("shards", Json::Number(SHARDS as f64)),
                    ("requests", Json::Number(requests as f64)),
                    ("inf_per_s", Json::Number(sharded_report.throughput())),
                    (
                        "deadline_flushes",
                        Json::Number(sharded_report.deadline_flushes() as f64),
                    ),
                    ("p99_latency_s", Json::Number(sharded_report.p99_latency_s())),
                ]),
            ),
            (
                "async",
                Json::object(vec![
                    ("shards", Json::Number(SHARDS as f64)),
                    ("offered_per_s", Json::Number(offered)),
                    ("admitted", Json::Number(admitted as f64)),
                    ("rejected", Json::Number(rejected as f64)),
                    ("inf_per_s", Json::Number(async_report.sharded.throughput())),
                    ("p99_queue_s", Json::Number(async_report.sharded.p99_queue_s())),
                    ("p99_latency_s", Json::Number(async_report.sharded.p99_latency_s())),
                    ("slot_allocs", Json::Number(async_report.slot_allocs as f64)),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string()).expect("writing the --json artifact");
        println!("\nwrote {path}");
    }
}
