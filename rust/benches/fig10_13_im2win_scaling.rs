//! Figs. 10–13 reproduction: im2win batch-size scaling on CHWN (Fig. 10),
//! CHWN8 (Fig. 11), NCHW (Fig. 12) and NHWC (Fig. 13).
//!
//! ```bash
//! cargo bench --bench fig10_13_im2win_scaling -- --scale ci --layers conv5,conv9
//! ```

mod common;

use im2win::conv::AlgoKind;
use im2win::coordinator::{experiments, write_csv};

fn main() {
    let mut cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("fig10_13_im2win_scaling: test mode, skipping measurement");
        return;
    }
    if cfg.layers.is_empty() {
        // Representative subset by default (small-C_i, large-C_i, mid, deep);
        // pass --layers conv1,...,conv12 for the full sweep.
        cfg.layers = ["conv1", "conv5", "conv9"]
            .map(String::from)
            .to_vec();
    }
    println!(
        "Figs. 10–13 — im2win batch scaling, sweep {:?}, scale={}",
        cfg.scale.batch_sweep(),
        cfg.scale.name()
    );
    let records = experiments::batch_scaling(&cfg, AlgoKind::Im2win).expect("scaling run failed");
    for (fig, layout) in
        [("fig10", "CHWN"), ("fig11", "CHWN8"), ("fig12", "NCHW"), ("fig13", "NHWC")]
    {
        let sub: Vec<_> =
            records.iter().filter(|r| r.experiment == fig).cloned().collect();
        println!(
            "\n{}",
            im2win::coordinator::plot::scaling_chart(
                &sub,
                &format!("[{fig} — im2win {layout}] batch scaling"),
                40
            )
        );
    }
    write_csv(format!("reports/fig10_13_{}.csv", cfg.scale.name()), &records).unwrap();
}
