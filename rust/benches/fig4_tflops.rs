//! Fig. 4 reproduction: TFLOPS of direct / im2win / im2col across layouts
//! on the twelve Table I layers (paper §IV-B, the headline figure).
//!
//! ```bash
//! cargo bench --bench fig4_tflops -- --scale ci          # minutes
//! cargo bench --bench fig4_tflops -- --scale full        # paper scale
//! cargo bench --bench fig4_tflops -- --layers conv5,conv9
//! ```
//!
//! Prints the per-layer grid, the winners count, the paper's headline
//! speedup comparisons, and writes `reports/fig4_<scale>.{csv,json}`.

mod common;

use im2win::coordinator::{experiments, format_table, plot, summary, write_csv, write_json};
use im2win::roofline::MachineSpec;

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("fig4_tflops: test mode, skipping measurement");
        return;
    }
    println!(
        "Fig. 4 — scale={} (batch {}, spatial/{}), {} repeats, {} threads",
        cfg.scale.name(),
        cfg.scale.batch(),
        cfg.scale.spatial_div(),
        cfg.scale.repeats(),
        im2win::parallel::global().threads()
    );
    let records = experiments::fig4(&cfg).expect("fig4 run failed");
    println!("\nGFLOPS (best of {} runs):", cfg.scale.repeats());
    println!("{}", format_table(&records, |r| format!("{:.1}", r.gflops())));

    let peak1 = MachineSpec::detect().peak_flops_single_core();
    println!("fraction of single-core Eq.4 peak ({:.0} GFLOPS):", peak1 / 1e9);
    println!(
        "{}",
        format_table(&records, |r| format!("{:.0}%", 100.0 * r.flops as f64 / r.best_s / peak1))
    );

    println!("winners per layer (paper: im2win 8/12, direct 3/12, im2col 1/12, all NHWC):");
    for (series, n) in summary::winners(&records) {
        println!("  {series:<16} {n}");
    }
    println!("\nheadline speedups (paper ranges in DESIGN.md §1):");
    for s in summary::paper_headlines(&records) {
        println!("  {s}");
    }
    write_csv(format!("reports/fig4_{}.csv", cfg.scale.name()), &records).unwrap();
    write_json(format!("reports/fig4_{}.json", cfg.scale.name()), &records).unwrap();
    // The figure itself, rendered offline.
    let chart = plot::bar_chart(&records, "\nFig. 4 (rendered)", "GFLOPS", 40, |r| r.gflops());
    println!("{chart}");
    std::fs::write(format!("reports/fig4_{}.txt", cfg.scale.name()), chart).unwrap();
}
