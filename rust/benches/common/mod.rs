//! Shared argument handling for the bench binaries.
//!
//! `cargo bench -- --scale ci --layers conv5,conv9` forwards everything
//! after `--` to each bench; `--bench` (injected by cargo) is ignored.

use im2win::config::{ExperimentConfig, Scale};

/// Parse the common bench flags into an experiment config.
pub fn config_from_args() -> ExperimentConfig {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut scale = Scale::Ci;
    let mut layers: Vec<String> = vec![];
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--scale" => {
                if let Some(v) = args.get(i + 1) {
                    scale = Scale::parse(v).unwrap_or_else(|| {
                        eprintln!("unknown scale '{v}', using ci");
                        Scale::Ci
                    });
                    i += 1;
                }
            }
            "--layers" => {
                if let Some(v) = args.get(i + 1) {
                    layers = v.split(',').map(str::to_string).collect();
                    i += 1;
                }
            }
            "--threads" => {
                if let Some(t) = args.get(i + 1).and_then(|v| v.parse().ok()) {
                    im2win::parallel::set_global_threads(t);
                    i += 1;
                }
            }
            _ => {} // --bench and friends
        }
        i += 1;
    }
    let mut cfg = ExperimentConfig::paper_matrix(scale);
    cfg.layers = layers;
    cfg
}

/// Skip heavy work under `cargo test --benches` smoke runs.
pub fn is_test_mode() -> bool {
    std::env::args().any(|a| a == "--test")
}

/// `--json PATH` argument: where to write the machine-readable results
/// (the CI bench-smoke job uploads this as the perf-trajectory artifact).
#[allow(dead_code)] // only the benches that emit JSON call this
pub fn json_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
}
