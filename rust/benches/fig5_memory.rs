//! Fig. 5 reproduction: memory usage of the three convolutions per layout.
//!
//! The paper's invariants this bench checks and prints:
//!   * direct uses the least memory (no transform buffers);
//!   * im2col uses the most (full patch matrix, ~Hf·Wf× the input);
//!   * im2win sits between (~Hf× the input): on average 1.5× direct and
//!     ~39% of im2col.
//!
//! ```bash
//! cargo bench --bench fig5_memory -- --scale ci
//! ```

mod common;

use im2win::coordinator::{experiments, format_table, summary, write_csv};

fn main() {
    let cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("fig5_memory: test mode, skipping measurement");
        return;
    }
    println!("Fig. 5 — memory usage, scale={} (batch {})", cfg.scale.name(), cfg.scale.batch());
    let records = experiments::fig5(&cfg).expect("fig5 run failed");
    println!("\npeak tensor MiB per convolution:");
    println!(
        "{}",
        format_table(&records, |r| format!("{:.2}", r.mem_bytes as f64 / (1024.0 * 1024.0)))
    );
    for layout in ["NCHW", "NHWC"] {
        if let Some((cd, wd, wc)) = summary::memory_ratios(&records, layout) {
            println!(
                "{layout}: im2col = {cd:.1}x direct (paper 3.9x) | im2win = {wd:.1}x direct (paper 1.5x) | im2win/im2col = {:.0}% (paper 39%)",
                wc * 100.0
            );
        }
    }
    write_csv(format!("reports/fig5_{}.csv", cfg.scale.name()), &records).unwrap();
}
