//! Figs. 6–9 reproduction: direct-convolution batch-size scaling on the
//! CHWN (Fig. 6), CHWN8 (Fig. 7), NCHW (Fig. 8) and NHWC (Fig. 9) layouts.
//!
//! Paper findings to observe in the output: CHWN is the most
//! batch-sensitive layout (best at the smallest batch); CHWN8 prefers
//! small batches when C_i is small (conv1–3) and large batches otherwise;
//! NCHW/NHWC are batch-insensitive.
//!
//! ```bash
//! cargo bench --bench fig6_9_direct_scaling -- --scale ci --layers conv5,conv9
//! ```

mod common;

use im2win::conv::AlgoKind;
use im2win::coordinator::{experiments, write_csv};

fn main() {
    let mut cfg = common::config_from_args();
    if common::is_test_mode() {
        println!("fig6_9_direct_scaling: test mode, skipping measurement");
        return;
    }
    if cfg.layers.is_empty() {
        // Representative subset by default (small-C_i, large-C_i, mid, deep);
        // pass --layers conv1,...,conv12 for the full sweep.
        cfg.layers = ["conv1", "conv5", "conv9"]
            .map(String::from)
            .to_vec();
    }
    println!(
        "Figs. 6–9 — direct conv batch scaling, sweep {:?}, scale={}",
        cfg.scale.batch_sweep(),
        cfg.scale.name()
    );
    let records = experiments::batch_scaling(&cfg, AlgoKind::Direct).expect("scaling run failed");
    for (fig, layout) in
        [("fig6", "CHWN"), ("fig7", "CHWN8"), ("fig8", "NCHW"), ("fig9", "NHWC")]
    {
        let sub: Vec<_> =
            records.iter().filter(|r| r.experiment == fig).cloned().collect();
        println!(
            "\n{}",
            im2win::coordinator::plot::scaling_chart(
                &sub,
                &format!("[{fig} — direct {layout}] batch scaling"),
                40
            )
        );
    }
    write_csv(format!("reports/fig6_9_{}.csv", cfg.scale.name()), &records).unwrap();
}
