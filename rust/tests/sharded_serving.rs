//! Integration tests for the sharded, deadline-aware serving front:
//! ≥2 shards processing concurrently, deadline-based flush under
//! `max_batch`, straggler isolation across shards, drain-on-shutdown,
//! and shard-aware plan keys.

use im2win::conv::AlgoKind;
use im2win::engine::{layer_key, Engine, Inference, PlanCache, Planner, ShardConfig, ShardedServer};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;
use std::sync::mpsc::TryRecvError;
use std::time::Duration;

const DIMS: Dims = Dims { n: 1, c: 3, h: 32, w: 32 };

fn tinynet_engine(threads: usize) -> Engine {
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let mut cache = PlanCache::in_memory();
    let planner = Planner { threads, ..Planner::new() };
    Engine::plan(model, &planner, &mut cache).unwrap()
}

fn image(seed: u64) -> Tensor4 {
    Tensor4::random(DIMS, Layout::Nchw, seed)
}

#[test]
fn two_shards_serve_concurrently_with_deadline_flush() {
    // Acceptance: 2 shards, each fed 4 requests — far under max_batch 16 —
    // with a 5 ms window. Results arriving while the server is still open
    // prove the flush came from the deadline, not from shutdown drain.
    let reference = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let engines = vec![tinynet_engine(1), tinynet_engine(1)];
    let cfg = ShardConfig {
        max_batch: 16,
        deadline: Duration::from_millis(5),
        threads_per_shard: 1,
        ..ShardConfig::default()
    };
    let server = ShardedServer::start(engines, cfg);
    assert_eq!(server.shards(), 2);

    let images: Vec<Tensor4> = (0..8).map(|i| image(300 + i)).collect();
    let rxs: Vec<_> = images
        .iter()
        .enumerate()
        .map(|(i, x)| server.submit_to(i % 2, x.clone()))
        .collect();
    for (x, rx) in images.iter().zip(&rxs) {
        let inf = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        let expect = reference.forward(x).unwrap();
        let got = inf.to_tensor(Layout::Nchw);
        assert!(
            expect.allclose(&got, 1e-3, 1e-4),
            "sharded result diverges: {}",
            expect.max_abs_diff(&got)
        );
    }

    let report = server.shutdown();
    assert_eq!(report.shards.len(), 2);
    assert_eq!(report.served(), 8);
    for (i, s) in report.shards.iter().enumerate() {
        assert_eq!(s.served, 4, "shard {i} served the wrong request count");
        assert!(s.max_batch_seen < 16, "shard {i}: a batch filled without enough requests");
        assert!(
            s.deadline_flushes >= 1,
            "shard {i}: under-full batches must flush at the deadline (batches={})",
            s.batches
        );
    }
}

#[test]
fn straggler_burst_on_one_shard_does_not_delay_the_other() {
    // 96 requests pinned to shard 0 (≥ 24 batched forwards at max_batch 4)
    // and a single request pinned to shard 1. If the shards truly run
    // concurrently, shard 1 answers its request while shard 0 is still
    // chewing through the burst.
    let engines = vec![tinynet_engine(1), tinynet_engine(1)];
    let cfg = ShardConfig { max_batch: 4, threads_per_shard: 1, ..ShardConfig::default() };
    let server = ShardedServer::start(engines, cfg);

    let burst: Vec<_> = (0..96).map(|i| server.submit_to(0, image(i))).collect();
    let lone = server.submit_to(1, image(7777));
    lone.recv_timeout(Duration::from_secs(60))
        .expect("shard 1 response blocked behind shard 0's burst")
        .unwrap();

    // Snapshot shard 0's progress the moment shard 1 answered.
    let mut results: Vec<Option<Inference>> = Vec::with_capacity(burst.len());
    let mut outstanding = 0;
    for rx in &burst {
        match rx.try_recv() {
            Ok(r) => results.push(Some(r.unwrap())),
            Err(TryRecvError::Empty) => {
                outstanding += 1;
                results.push(None);
            }
            Err(TryRecvError::Disconnected) => panic!("shard 0 dropped a burst request"),
        }
    }
    assert!(
        outstanding > 0,
        "shard 0 finished its 96-request burst before shard 1 served one request — \
         the straggler shard is serializing the front"
    );

    // Every burst request still completes.
    for (rx, slot) in burst.iter().zip(&mut results) {
        if slot.is_none() {
            *slot = Some(rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap());
        }
    }
    assert!(results.iter().all(|r| r.is_some()));

    let report = server.shutdown();
    assert_eq!(report.shards[0].served, 96);
    assert_eq!(report.shards[1].served, 1);
}

#[test]
fn batches_flush_at_the_deadline_when_under_max_batch() {
    // 3 requests against max_batch 32: the batch can never fill, so only
    // the deadline (10 ms) can flush it — and the results must arrive
    // while the server is still accepting requests.
    let server = ShardedServer::start(
        vec![tinynet_engine(1)],
        ShardConfig {
            max_batch: 32,
            deadline: Duration::from_millis(10),
            threads_per_shard: 1,
            ..ShardConfig::default()
        },
    );
    let rxs: Vec<_> = (0..3).map(|i| server.submit(image(40 + i))).collect();
    for rx in &rxs {
        rx.recv_timeout(Duration::from_secs(60))
            .expect("an under-full batch never flushed before shutdown")
            .unwrap();
    }
    let report = server.shutdown();
    let s = &report.shards[0];
    assert_eq!(s.served, 3);
    assert!(s.deadline_flushes >= 1, "no deadline flush recorded (batches={})", s.batches);
    assert_eq!(s.full_flushes, 0, "a 3-request load can never fill max_batch 32");
    assert!(s.max_batch_seen <= 3);
}

#[test]
fn sharded_shutdown_drains_every_shard_queue() {
    // Regression for the drop-on-shutdown bug: queue up work on both
    // shards, shut down immediately, and require every request answered.
    let engines = vec![tinynet_engine(1), tinynet_engine(1)];
    let cfg = ShardConfig {
        max_batch: 8,
        deadline: Duration::from_millis(1),
        threads_per_shard: 1,
        ..ShardConfig::default()
    };
    let server = ShardedServer::start(engines, cfg);
    let rxs: Vec<_> = (0..24).map(|i| server.submit(image(500 + i))).collect();
    let report = server.shutdown();
    assert_eq!(report.served(), 24, "shutdown dropped queued requests");
    assert_eq!(report.served(), report.shards.iter().map(|s| s.served).sum::<usize>());
    for rx in &rxs {
        rx.try_recv().expect("a queued request was dropped at shutdown").unwrap();
    }
}

#[test]
fn sharded_engines_plan_under_per_shard_cache_keys() {
    // Planning 2 shards of an 8-thread machine must read/write the cache
    // under threads=4 keys, disjoint from the whole-machine threads=8 keys.
    let planner = Planner { threads: 8, ..Planner::new() };
    let shard_planner = planner.for_shards(2);
    assert_eq!(shard_planner.threads, 4);

    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 3).unwrap();
    let mut cache = PlanCache::in_memory();
    planner.plan_model(&model, &mut cache).unwrap();
    let whole_machine_entries = cache.len();
    shard_planner.plan_model(&model, &mut cache).unwrap();
    assert_eq!(
        cache.len(),
        2 * whole_machine_entries,
        "sharded planning must not reuse whole-machine cache entries"
    );

    let p = ConvParams::builder().batch(8).channels(3, 16).input(32, 32).filter(3, 3).stride(1).build().unwrap();
    assert_ne!(
        layer_key(&p, Layout::Nchw, planner.threads),
        layer_key(&p, Layout::Nchw, shard_planner.threads)
    );
}
