//! Generalized-geometry conformance suite: padding, dilation, strides,
//! grouped channels and depthwise must agree with the generalized naive
//! oracle across every algorithm × layout pair that supports them, with
//! every epilogue fused on the prepacked path. Outputs are NaN-poisoned
//! and transform scratch is recycled through one shared [`Workspace`],
//! so a kernel that skips an output element or trusts stale scratch
//! fails loudly instead of passing on leftover zeros.

use im2win::conv::winograd::winograd_ok;
use im2win::conv::{reference_conv, AlgoKind, ConvParams};
use im2win::engine::{layer_key, LayerPlan, PlanCache, Workspace};
use im2win::prelude::*;

/// One named geometry per generalized feature, plus combinations.
/// Batches straddle the CHWN8 block boundary and channels are chosen so
/// NHWC kernels hit both full-vector and scalar-tail channel counts.
fn geometries() -> Vec<(&'static str, ConvParams)> {
    let g = |b: im2win::conv::ConvParamsBuilder| b.build().unwrap();
    vec![
        (
            "padded",
            g(ConvParams::builder().batch(2).channels(3, 4).input(6, 7).filter(3, 3).pad(1)),
        ),
        (
            "dilated",
            g(ConvParams::builder().batch(3).channels(4, 2).input(9, 8).filter(3, 3).dilation(2)),
        ),
        (
            "strided_padded",
            g(ConvParams::builder()
                .batch(9)
                .channels(2, 3)
                .input(10, 9)
                .filter(3, 2)
                .stride(2)
                .pad_hw(2, 1)),
        ),
        (
            "padded_dilated",
            g(ConvParams::builder()
                .batch(2)
                .channels(3, 3)
                .input(8, 8)
                .filter(3, 3)
                .pad(2)
                .dilation_hw(2, 1)),
        ),
        (
            "grouped",
            g(ConvParams::builder().batch(2).channels(4, 6).input(7, 7).filter(3, 3).pad(1).groups(2)),
        ),
        (
            "depthwise",
            g(ConvParams::builder().batch(2).channels(6, 6).input(7, 6).filter(3, 3).pad(1).groups(6)),
        ),
        (
            "depthwise_wide_strided",
            g(ConvParams::builder()
                .batch(9)
                .channels(11, 11)
                .input(10, 10)
                .filter(3, 3)
                .stride(2)
                .pad(1)
                .groups(11)),
        ),
    ]
}

/// NaN-poisoned output tensor: every logical element the kernel fails to
/// overwrite shows up as a NaN mismatch, never as a lucky zero.
fn poisoned(p: &ConvParams, layout: Layout) -> Tensor4 {
    let mut out = Tensor4::zeros(p.output_dims(), layout);
    for v in out.data_mut() {
        *v = f32::NAN;
    }
    out
}

/// Every supported algorithm × layout pair vs the generalized oracle,
/// through `run_with_workspace` with one workspace recycled across the
/// whole sweep (the second geometry onward runs on reused scratch).
#[test]
fn generalized_geometries_match_reference_in_all_layouts() {
    let mut ws = Workspace::new();
    for (name, p) in geometries() {
        for (i, layout) in Layout::ALL.into_iter().enumerate() {
            let seed = 900 + i as u64;
            let input = Tensor4::random(p.input_dims(), layout, seed);
            let filter = Tensor4::random(p.filter_dims(), layout, seed + 1);
            let expect = reference_conv(&input, &filter, &p, layout);
            for algo in AlgoKind::ALL {
                let algorithm = algo.build();
                if !algorithm.supports(layout) {
                    continue;
                }
                if algo == AlgoKind::Depthwise && !p.is_depthwise() {
                    continue;
                }
                // Winograd F(2×2, 3×3) is dense/stride-1 only by design;
                // its own suite asserts it *rejects* these geometries.
                if algo == AlgoKind::Winograd && !winograd_ok(&p) {
                    continue;
                }
                let mut out = poisoned(&p, layout);
                algorithm
                    .run_with_workspace(&input, &filter, &p, &mut out, &mut ws)
                    .unwrap_or_else(|e| panic!("{name} {algo} {layout} {p}: {e}"));
                assert!(
                    expect.allclose(&out, 1e-4, 1e-4),
                    "{name} {algo} {layout} {p}: max diff {}",
                    expect.max_abs_diff(&out)
                );
            }
        }
    }
}

/// The prepacked serving path with every epilogue fused, on generalized
/// geometry: prepare once, then run on poisoned outputs with recycled
/// workspace scratch, against `reference_conv` + a separate epilogue
/// pass.
#[test]
fn prepacked_epilogues_match_on_generalized_geometry() {
    let mut ws = Workspace::new();
    for (name, p) in geometries() {
        let bias: Vec<f32> = (0..p.c_out).map(|c| 0.1 * c as f32 - 0.3).collect();
        let epilogues = [
            Epilogue::None,
            Epilogue::Relu,
            Epilogue::Bias(&bias),
            Epilogue::BiasRelu(&bias),
        ];
        for layout in Layout::ALL {
            let input = Tensor4::random(p.input_dims(), layout, 77);
            let filter = Tensor4::random(p.filter_dims(), layout, 78);
            for algo in AlgoKind::ALL {
                let algorithm = algo.build();
                if !algorithm.supports(layout) {
                    continue;
                }
                if algo == AlgoKind::Depthwise && !p.is_depthwise() {
                    continue;
                }
                if algo == AlgoKind::Winograd && !winograd_ok(&p) {
                    continue;
                }
                let packed = algorithm
                    .prepare(&filter, &p, layout)
                    .unwrap_or_else(|e| panic!("{name} {algo} {layout}: prepare: {e}"));
                for ep in epilogues {
                    let mut expect = reference_conv(&input, &filter, &p, layout);
                    ep.apply_to(&mut expect);
                    let mut out = poisoned(&p, layout);
                    algorithm
                        .run_prepacked(&input, &packed, &p, &mut out, &mut ws, ep)
                        .unwrap_or_else(|e| panic!("{name} {algo} {layout} {ep:?}: {e}"));
                    assert!(
                        expect.allclose(&out, 1e-4, 1e-4),
                        "{name} {algo} {layout} {ep:?} {p}: max diff {}",
                        expect.max_abs_diff(&out)
                    );
                }
            }
        }
    }
}

/// A plan cache recorded before geometry generalization (dense keys
/// only) must never serve a plan for a padded/dilated/grouped layer:
/// the generalized key always carries the geometry suffix, and the
/// dense key is byte-identical to the pre-generalization format.
#[test]
fn preexisting_cache_never_serves_generalized_geometry() {
    let dense = ConvParams::builder()
        .batch(2)
        .channels(4, 4)
        .input(8, 8)
        .filter(3, 3)
        .build()
        .unwrap();
    let key = layer_key(&dense, Layout::Nchw, 4);
    // The exact pre-generalization key format — a cache file written
    // before padding/dilation/groups existed holds keys of this shape.
    assert_eq!(key, "n2c4x8x8-o4f3x3s1x1-from_nchw-t4");

    let mut cache = PlanCache::in_memory();
    cache.insert(
        key.clone(),
        LayerPlan {
            algo: AlgoKind::Im2win,
            layout: Layout::Nhwc,
            w_block: 4,
            est_s: 1e-4,
            tuned: false,
            precision: Precision::F32,
        },
    );
    assert!(cache.get(&key).is_some(), "dense key must keep serving");

    // Same core dims with generalized geometry: every variant must miss.
    let variants = [
        ConvParams::builder().batch(2).channels(4, 4).input(8, 8).filter(3, 3).pad(1),
        ConvParams::builder().batch(2).channels(4, 4).input(8, 8).filter(3, 3).dilation(2),
        ConvParams::builder().batch(2).channels(4, 4).input(8, 8).filter(3, 3).groups(2),
        ConvParams::builder().batch(2).channels(4, 4).input(8, 8).filter(3, 3).pad(1).groups(4),
    ];
    for b in variants {
        let p = b.build().unwrap();
        let k = layer_key(&p, Layout::Nchw, 4);
        assert_ne!(k, key, "{p} aliases the dense key");
        assert!(cache.get(&k).is_none(), "{p} served a pre-generalization plan");
    }
}

/// Depthwise must also hold together end to end under the planner's
/// chosen algorithm: a depthwise layer planned analytically runs and
/// matches the oracle (regression net for AlgoKind::Depthwise wiring).
#[test]
fn planned_depthwise_layer_executes_and_matches() {
    use im2win::engine::Planner;
    let p = ConvParams::builder()
        .batch(8)
        .channels(16, 16)
        .input(12, 12)
        .filter(3, 3)
        .pad(1)
        .groups(16)
        .build()
        .unwrap();
    let planner = Planner { batch: p.n, ..Planner::new() };
    let plan = planner.plan_conv(&p, Layout::Nhwc);
    assert_eq!(plan.algo, AlgoKind::Depthwise, "planner skipped the depthwise specialist");
    let algorithm = plan.algo.build_tuned(plan.w_block);
    let input = Tensor4::random(p.input_dims(), plan.layout, 5);
    let filter = Tensor4::random(p.filter_dims(), plan.layout, 6);
    let expect = reference_conv(&input, &filter, &p, plan.layout);
    let mut ws = Workspace::new();
    let mut out = poisoned(&p, plan.layout);
    algorithm.run_with_workspace(&input, &filter, &p, &mut out, &mut ws).unwrap();
    assert!(expect.allclose(&out, 1e-4, 1e-4), "max diff {}", expect.max_abs_diff(&out));
}
