//! Integration tests for the inference engine subsystem: plan selection
//! over the zoo models, persistent plan-cache round trips, workspace-reuse
//! correctness (stale-scratch detection) across all four layouts, and the
//! micro-batching server's end-to-end contract.

use im2win::conv::{reference_conv, AlgoKind};
use im2win::engine::{layer_key, Engine, Inference, LayerPlan, PlanCache, Planner, Server};
use im2win::model::{zoo, Model};
use im2win::prelude::*;
use im2win::tensor::Dims;
use im2win::testutil::random_problems;

fn temp_path(stem: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("im2win_engine_{}_{stem}", std::process::id()))
}

/// A single-conv model (plus filter copy) for layer-level engine checks.
fn single_conv_model(p: ConvParams, seed: u64) -> (Model, Tensor4) {
    let filter = Tensor4::random(p.filter_dims(), Layout::Nchw, seed);
    let model = Model::new("one_conv", Layout::Nchw, p.c_in, p.h_in, p.w_in)
        .conv(p.with_batch(1), AlgoKind::Naive, &filter)
        .unwrap();
    (model, filter)
}

// ---------------------------------------------------------------- planner

#[test]
fn planner_plans_every_layer_of_both_zoo_models() {
    let planner = Planner::new();
    for model in [
        zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 3).unwrap(),
        zoo::vgg_stack(Layout::Nchw, AlgoKind::Naive, 32, 3).unwrap(),
    ] {
        let mut cache = PlanCache::in_memory();
        let plans = planner.plan_model(&model, &mut cache).unwrap();
        assert_eq!(
            plans.len(),
            model.conv_params().len(),
            "{}: every conv layer needs a plan",
            model.name
        );
        for plan in &plans {
            assert!(plan.algo.build().supports(plan.layout), "{}", model.name);
            assert_ne!(plan.algo, AlgoKind::Naive);
            assert!(plan.est_s > 0.0 && plan.est_s.is_finite());
        }
    }
}

#[test]
fn engine_runs_zoo_models_without_user_choices() {
    // The acceptance path: user supplies geometry only (Naive/Nchw are
    // placeholders), the engine picks algorithm x layout per layer and the
    // result matches the oracle model.
    let x = Tensor4::random(Dims::new(2, 3, 32, 32), Layout::Nchw, 70);
    let expect = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 8).unwrap().forward(&x).unwrap();
    let mut cache = PlanCache::in_memory();
    let mut engine = Engine::plan(
        zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 8).unwrap(),
        &Planner::new(),
        &mut cache,
    )
    .unwrap();
    let y = engine.forward(&x).unwrap();
    assert!(expect.allclose(&y, 1e-3, 1e-4), "diff {}", expect.max_abs_diff(&y));
}

// ------------------------------------------------------------ plan cache

#[test]
fn plan_cache_save_load_round_trips_byte_identically() {
    // Property: for randomized geometries, save -> load -> save produces
    // byte-identical files (canonical serialization).
    let planner = Planner::new();
    let path = temp_path("roundtrip.json");
    let mut cache = PlanCache::load(&path).unwrap();
    for (i, p) in random_problems(15, 404).iter().enumerate() {
        let prev = Layout::ALL[i % 4];
        let plan = planner.plan_conv(p, prev);
        cache.insert(layer_key(p, prev, 1 + i % 3), plan);
    }
    cache.save().unwrap();
    let bytes1 = std::fs::read(&path).unwrap();

    let reloaded = PlanCache::load(&path).unwrap();
    assert_eq!(reloaded.len(), cache.len());
    reloaded.save().unwrap();
    let bytes2 = std::fs::read(&path).unwrap();
    assert_eq!(bytes1, bytes2, "canonical serialization must be byte-stable");
    std::fs::remove_file(&path).ok();
}

#[test]
fn second_process_run_hits_the_persisted_cache() {
    let path = temp_path("persist.json");
    std::fs::remove_file(&path).ok();
    let planner = Planner::new();
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 1).unwrap();

    // "First process": plan from scratch and persist.
    let first_plans;
    {
        let mut cache = PlanCache::load(&path).unwrap();
        first_plans = planner.plan_model(&model, &mut cache).unwrap();
        assert_eq!(cache.misses(), first_plans.len());
        assert_eq!(cache.hits(), 0);
        cache.save().unwrap();
    }

    // "Second process": a fresh load answers every layer from disk.
    {
        let mut cache = PlanCache::load(&path).unwrap();
        let again = planner.plan_model(&model, &mut cache).unwrap();
        assert_eq!(again, first_plans);
        assert_eq!(cache.hits(), first_plans.len(), "all layers must be cache hits");
        assert_eq!(cache.misses(), 0, "a second run must not re-plan or re-tune");
    }
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------- workspace-reuse correctness

#[test]
fn engine_matches_reference_conv_across_layouts_and_repeats() {
    // Acceptance: engine output with workspace reuse matches
    // `reference_conv` within 1e-5 on every layout x algorithm, and stays
    // bit-identical across repeated calls (stale-scratch detection).
    let p = ConvParams::builder().batch(3).channels(4, 5).input(10, 10).filter(3, 3).stride(1).build().unwrap();
    let x = Tensor4::random(p.input_dims(), Layout::Nchw, 31);
    for layout in Layout::ALL {
        for algo in [AlgoKind::Direct, AlgoKind::Im2win, AlgoKind::Im2col, AlgoKind::Mec] {
            if !algo.build().supports(layout) {
                continue;
            }
            let (model, filter) = single_conv_model(p, 32);
            let expect = reference_conv(
                &x.to_layout(layout),
                &filter.to_layout(layout),
                &p,
                layout,
            );
            let plan = LayerPlan {
                algo,
                layout,
                w_block: 3,
                est_s: 1.0,
                tuned: false,
                precision: Precision::F32,
            };
            let mut engine = Engine::with_plans(model, vec![plan]).unwrap();
            let mut outputs = Vec::new();
            for _ in 0..3 {
                outputs.push(engine.forward(&x).unwrap());
            }
            for y in &outputs {
                assert!(
                    expect.allclose(y, 1e-5, 1e-5),
                    "{algo} {layout}: diff {} vs reference_conv",
                    expect.max_abs_diff(y)
                );
            }
            assert_eq!(
                outputs[0].data(),
                outputs[1].data(),
                "{algo} {layout}: repeated forwards must be identical"
            );
            assert_eq!(outputs[1].data(), outputs[2].data(), "{algo} {layout}");
        }
    }
}

#[test]
fn interleaved_batch_sizes_do_not_cross_contaminate() {
    // Alternating batch sizes exercises the per-size slots: a stale buffer
    // from one size must never leak into the other.
    let (model, _) = single_conv_model(ConvParams::builder().batch(1).channels(3, 4).input(9, 9).filter(2, 2).stride(1).build().unwrap(), 55);
    let plan = LayerPlan {
        algo: AlgoKind::Im2win,
        layout: Layout::Nhwc,
        w_block: 2,
        est_s: 1.0,
        tuned: false,
        precision: Precision::F32,
    };
    let mut engine = Engine::with_plans(model, vec![plan]).unwrap();
    let p2 = ConvParams::builder().batch(2).channels(3, 4).input(9, 9).filter(2, 2).stride(1).build().unwrap();
    let p5 = ConvParams::builder().batch(5).channels(3, 4).input(9, 9).filter(2, 2).stride(1).build().unwrap();
    let x2 = Tensor4::random(p2.input_dims(), Layout::Nchw, 81);
    let x5 = Tensor4::random(p5.input_dims(), Layout::Nchw, 82);
    let first2 = engine.forward(&x2).unwrap();
    let first5 = engine.forward(&x5).unwrap();
    for _ in 0..3 {
        assert_eq!(engine.forward(&x2).unwrap().data(), first2.data());
        assert_eq!(engine.forward(&x5).unwrap().data(), first5.data());
    }
    // Both sizes warmed: a further interleaved round allocates nothing.
    let misses = engine.workspace().misses();
    engine.forward(&x2).unwrap();
    engine.forward(&x5).unwrap();
    assert_eq!(engine.workspace().misses(), misses);
}

// -------------------------------------------- prepacked serving steady state

#[test]
fn serving_steady_state_packs_each_filter_exactly_once() {
    // The weights-stationary contract: every conv filter is packed
    // exactly once, at plan time — never on the request path. The pack
    // counter is thread-local and packing happens on the calling thread,
    // so concurrent tests cannot perturb this count.
    let model = zoo::tinynet_biased(Layout::Nchw, AlgoKind::Naive, 5).unwrap();
    let n_convs = model.conv_params().len();
    let before = im2win::conv::filter_pack_count();
    let mut cache = PlanCache::in_memory();
    let mut engine = Engine::plan(model, &Planner::new(), &mut cache).unwrap();
    assert_eq!(
        im2win::conv::filter_pack_count() - before,
        n_convs,
        "plan time must pack exactly once per conv layer"
    );
    assert_eq!(engine.packed_filters().len(), n_convs);

    // Warm up both batch sizes the steady-state loop uses.
    let x1 = Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, 31);
    let x4 = Tensor4::random(Dims::new(4, 3, 32, 32), Layout::Nchw, 32);
    let first1 = engine.forward(&x1).unwrap();
    let first4 = engine.forward(&x4).unwrap();
    let packs_warm = im2win::conv::filter_pack_count();
    let misses_warm = engine.workspace().misses();

    for _ in 0..10 {
        assert_eq!(engine.forward(&x1).unwrap().data(), first1.data());
        assert_eq!(engine.forward(&x4).unwrap().data(), first4.data());
    }
    assert_eq!(
        im2win::conv::filter_pack_count(),
        packs_warm,
        "steady-state serving re-packed a filter"
    );
    assert_eq!(
        engine.workspace().misses(),
        misses_warm,
        "steady-state serving allocated new scratch"
    );
    assert!(engine.workspace().hits() > 0);
}

// ----------------------------------------------------------------- server

#[test]
fn server_serves_100_requests_with_no_warm_allocations() {
    // Acceptance: 100 single-image requests through the server produce
    // outputs matching reference_conv within 1e-5, and no new scratch
    // buffers are allocated after warmup.
    let p = ConvParams::builder().batch(1).channels(3, 4).input(12, 12).filter(3, 3).stride(1).build().unwrap();
    let (model, filter) = single_conv_model(p, 91);
    let mut cache = PlanCache::in_memory();
    let engine = Engine::plan(model, &Planner::new(), &mut cache).unwrap();
    let server = Server::start(engine, 8);

    let images: Vec<Tensor4> =
        (0..100).map(|i| Tensor4::random(p.input_dims(), Layout::Nchw, 900 + i)).collect();
    let receivers: Vec<_> = images.iter().map(|x| server.submit(x.clone())).collect();
    let results: Vec<Inference> =
        receivers.iter().map(|rx| rx.recv().unwrap().unwrap()).collect();

    for (x, inf) in images.iter().zip(&results) {
        let expect = reference_conv(x, &filter, &p, Layout::Nchw);
        let got = inf.to_tensor(Layout::Nchw);
        assert!(
            expect.allclose(&got, 1e-5, 1e-5),
            "served output diverges from reference_conv: {}",
            expect.max_abs_diff(&got)
        );
    }

    let report = server.shutdown();
    assert_eq!(report.served, 100);
    assert!(report.batches >= 100usize.div_ceil(8), "batches={}", report.batches);
    assert_eq!(
        report.warm_misses, 0,
        "steady-state serving must not allocate scratch (saw {} warm misses)",
        report.warm_misses
    );
    assert!(report.throughput() > 0.0);
}

#[test]
fn server_handles_mixed_request_layouts() {
    let reference = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 14).unwrap();
    let mut cache = PlanCache::in_memory();
    let engine = Engine::plan(
        zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 14).unwrap(),
        &Planner::new(),
        &mut cache,
    )
    .unwrap();
    let server = Server::start(engine, 4);
    let dims = Dims::new(1, 3, 32, 32);
    let images: Vec<Tensor4> = Layout::ALL
        .iter()
        .enumerate()
        .map(|(i, &l)| Tensor4::random(dims, l, 600 + i as u64))
        .collect();
    let receivers: Vec<_> = images.iter().map(|x| server.submit(x.clone())).collect();
    for (x, rx) in images.iter().zip(&receivers) {
        let inf = rx.recv().unwrap().unwrap();
        let expect = reference.forward(x).unwrap();
        let got = inf.to_tensor(Layout::Nchw);
        assert!(
            expect.allclose(&got, 1e-3, 1e-4),
            "layout {}: diff {}",
            x.layout(),
            expect.max_abs_diff(&got)
        );
    }
    server.shutdown();
}
