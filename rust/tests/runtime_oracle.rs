//! Integration: the Rust kernels vs the JAX/Pallas XLA oracle through the
//! PJRT runtime — the cross-stack numerical contract.
//!
//! Requires `make artifacts`; tests skip (with a loud message) when the
//! artifacts directory is absent so `cargo test` still works in a fresh
//! checkout. The whole suite is gated on the `pjrt-sys` feature — both
//! the default offline build and the binding-free `--features pjrt` build
//! ship only the stub runtime (see `src/runtime/mod.rs`).
#![cfg(feature = "pjrt-sys")]

use im2win::conv::AlgoKind;
use im2win::coordinator::layers;
use im2win::prelude::*;
use im2win::runtime::{artifact_path, tensor_to_literal, PjrtRuntime};
use im2win::tensor::Dims;

fn have_artifacts() -> bool {
    let ok = artifact_path("conv_conv9").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts` to enable runtime tests");
    }
    ok
}

/// Oracle geometry must mirror aot.py: scaled_params(2, 8).
fn oracle_params(name: &str) -> ConvParams {
    layers::by_name(name).unwrap().scaled_params(2, 8)
}

fn check_layer_against_oracle(rt: &PjrtRuntime, name: &str) {
    let p = oracle_params(name);
    let module = rt.load_hlo_text(artifact_path(&format!("conv_{name}"))).unwrap();
    let input = Tensor4::random(p.input_dims(), Layout::Nhwc, 42);
    let filter = Tensor4::random(p.filter_dims(), Layout::Nhwc, 43);
    let outs = module.execute_tensors(&[&input, &filter]).unwrap();
    let oracle = Tensor4::from_logical(p.output_dims(), Layout::Nhwc, &outs[0]);
    // Tolerance scales with the reduction length.
    let tol = 1e-5 * (p.c_in * p.h_f * p.w_f) as f32;
    for algo in AlgoKind::BENCHED {
        for layout in Layout::ALL {
            if algo == AlgoKind::Im2col && matches!(layout, Layout::Chwn | Layout::Chwn8) {
                continue;
            }
            let got = algo
                .build()
                .run(&input.to_layout(layout), &filter.to_layout(layout), &p)
                .unwrap();
            let diff = oracle.max_abs_diff(&got);
            assert!(diff < tol, "{name} {algo} {layout}: diff {diff} > {tol}");
        }
    }
}

#[test]
fn rust_kernels_match_xla_oracle_small_layers() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    // Layers spanning the suite's regimes: tiny C_i + big filter (conv1),
    // mid (conv9), channel-heavy (conv12).
    for name in ["conv1", "conv9", "conv12"] {
        check_layer_against_oracle(&rt, name);
    }
}

#[test]
fn rust_kernels_match_xla_oracle_remaining_layers() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    for name in ["conv3", "conv5", "conv6"] {
        check_layer_against_oracle(&rt, name);
    }
}

#[test]
fn tinynet_fwd_artifact_runs_and_is_deterministic() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let module = rt.load_hlo_text(artifact_path("tinynet_fwd")).unwrap();
    let x = Tensor4::random(Dims::new(4, 3, 32, 32), Layout::Nchw, 1);
    let mk = |dims: &[i64], seed: u64| {
        let len = dims.iter().product::<i64>() as usize;
        let mut rng = im2win::testutil::Rng::new(seed);
        let data: Vec<f32> = (0..len).map(|_| rng.f32() * 0.1).collect();
        xla::Literal::vec1(&data).reshape(dims).unwrap()
    };
    let inputs = vec![
        tensor_to_literal(&x).unwrap(),
        mk(&[16, 3, 3, 3], 2),
        mk(&[32, 3, 3, 16], 3),
        mk(&[32, 3, 3, 32], 4),
        mk(&[10, 32], 5),
    ];
    let out1 = module.execute(&inputs).unwrap();
    let logits1 = im2win::runtime::literal_to_vec(&out1[0]).unwrap();
    assert_eq!(logits1.len(), 4 * 10);
    assert!(logits1.iter().all(|v| v.is_finite()));
    let out2 = module.execute(&inputs).unwrap();
    let logits2 = im2win::runtime::literal_to_vec(&out2[0]).unwrap();
    assert_eq!(logits1, logits2);
}

#[test]
fn train_step_artifact_decreases_loss() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let module = rt.load_hlo_text(artifact_path("tinynet_train")).unwrap();
    let mut rng = im2win::testutil::Rng::new(7);
    let xs: Vec<f32> = (0..16 * 3 * 32 * 32).map(|_| rng.f32()).collect();
    let ys: Vec<i32> = (0..16).map(|_| rng.int(0, 9) as i32).collect();
    let x = xla::Literal::vec1(&xs).reshape(&[16, 3, 32, 32]).unwrap();
    let y = xla::Literal::vec1(&ys).reshape(&[16]).unwrap();
    let mkw = |dims: &[i64], seed: u64, scale: f32| {
        let len = dims.iter().product::<i64>() as usize;
        let mut rng = im2win::testutil::Rng::new(seed);
        let data: Vec<f32> = (0..len).map(|_| rng.f32() * scale).collect();
        xla::Literal::vec1(&data).reshape(dims).unwrap()
    };
    let mut weights = vec![
        mkw(&[16, 3, 3, 3], 11, 0.27),
        mkw(&[32, 3, 3, 16], 12, 0.12),
        mkw(&[32, 3, 3, 32], 13, 0.08),
        mkw(&[10, 32], 14, 0.01),
    ];
    let lr = xla::Literal::vec1(&[0.05f32]).reshape(&[]).unwrap();
    let mut losses = Vec::new();
    for _ in 0..6 {
        let mut inputs = vec![
            x.to_vec::<f32>().map(|v| xla::Literal::vec1(&v).reshape(&[16, 3, 32, 32]).unwrap()).unwrap(),
            y.to_vec::<i32>().map(|v| xla::Literal::vec1(&v).reshape(&[16]).unwrap()).unwrap(),
        ];
        inputs.append(&mut weights);
        inputs.push(lr.to_vec::<f32>().map(|v| xla::Literal::vec1(&v).reshape(&[]).unwrap()).unwrap());
        let outs = module.execute(&inputs).unwrap();
        assert_eq!(outs.len(), 5);
        losses.push(im2win::runtime::literal_to_vec(&outs[0]).unwrap()[0]);
        weights = outs.into_iter().skip(1).collect();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}
