//! Integration tests for measured cost-model calibration: fitting a
//! [`CalibrationProfile`] from coordinator reports (CSV and JSON), the
//! profile provably changing the planner's decisions, fingerprint-driven
//! plan-cache invalidation end to end through files, warm-packing the
//! Table I suite, and the monotonicity property of calibrated estimates.

use im2win::config::{ExperimentConfig, Scale};
use im2win::conv::AlgoKind;
use im2win::coordinator::{by_name, experiments, read_csv, read_json, write_csv, write_json};
use im2win::coordinator::{Record, TABLE1};
use im2win::engine::{layer_key, warm_pack, CalibrationProfile, PlanCache, Planner};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::testutil::random_problems;

fn temp_dir(stem: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("im2win_calib_{}_{stem}", std::process::id()))
}

/// A timed record with dyadic best_s so the CSV writer's 7-significant-
/// digit float formatting is exact and CSV/JSON round trips agree bit
/// for bit.
fn record(layer: &str, algo: &str, layout: &str, best_s: f64) -> Record {
    Record {
        experiment: "fig4".into(),
        layer: layer.into(),
        algo: algo.into(),
        layout: layout.into(),
        batch: 8,
        best_s,
        median_s: best_s * 2.0,
        flops: 1_000_000_000,
        mem_bytes: 2048,
    }
}

/// Records that invert the analytic preference on conv12: im2col/NCHW
/// measures an order of magnitude faster than the im2win/direct cells.
fn conv12_upset() -> Vec<Record> {
    vec![
        record("conv12", "im2col", "NCHW", 0.0078125), // 128 GFLOPS
        record("conv12", "im2win", "NHWC", 0.5),       // 2 GFLOPS
        record("conv12", "direct", "NHWC", 0.5),
    ]
}

#[test]
fn fit_agrees_between_csv_and_json_reports() {
    let dir = temp_dir("formats");
    let records = vec![
        record("conv9", "im2win", "NHWC", 0.0625),
        record("conv9", "direct", "NHWC", 0.125),
        record("conv12", "im2col", "NCHW", 0.25),
        record("conv1", "im2win", "CHWN8", 0.5),
    ];
    let csv_path = dir.join("r.csv");
    let json_path = dir.join("r.json");
    write_csv(&csv_path, &records).unwrap();
    write_json(&json_path, &records).unwrap();
    let from_csv = CalibrationProfile::fit(&read_csv(&csv_path).unwrap(), 2).unwrap();
    let from_json = CalibrationProfile::fit(&read_json(&json_path).unwrap(), 2).unwrap();
    assert_eq!(from_csv, from_json);
    assert_eq!(from_csv.fingerprint(), from_json.fingerprint());
    assert_eq!(from_csv.to_json_text(), from_json.to_json_text());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn profile_save_load_round_trips_byte_identically() {
    // Like the plan cache: save → load → save is byte-identical.
    let dir = temp_dir("roundtrip");
    let path = dir.join("profile.json");
    let profile = CalibrationProfile::fit(&conv12_upset(), 3).unwrap();
    profile.save(&path).unwrap();
    let text1 = std::fs::read_to_string(&path).unwrap();
    let back = CalibrationProfile::load(&path).unwrap();
    back.save(&path).unwrap();
    let text2 = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text1, text2);
    assert_eq!(back, profile);
    assert_eq!(back.fingerprint(), profile.fingerprint());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn calibration_provably_changes_a_plan() {
    // The acceptance check: the same geometry plans differently under
    // the fitted model than under the analytic constants.
    let profile = CalibrationProfile::fit(&conv12_upset(), 1).unwrap();
    let analytic = Planner { threads: 1, batch: 8, ..Planner::new() };
    let calibrated = Planner { profile: Some(profile), ..analytic.clone() };
    let p = by_name("conv12").unwrap().params(8);
    let a = analytic.plan_conv(&p, Layout::Nchw);
    let c = calibrated.plan_conv(&p, Layout::Nchw);
    assert_ne!(
        (a.algo, a.layout),
        (c.algo, c.layout),
        "measured upset must change the plan (analytic {}/{}, calibrated {}/{})",
        a.algo,
        a.layout,
        c.algo,
        c.layout
    );
    // And it changes it *toward* the measurement's rank-1 cell.
    assert_eq!((c.algo, c.layout), (AlgoKind::Im2col, Layout::Nchw));
}

#[test]
fn estimate_is_monotone_in_measured_efficiency_across_problems() {
    // Property: better measured efficiency ⇒ strictly lower estimated
    // cost, all else equal, for every geometry and candidate series.
    for p in random_problems(8, 77) {
        for (algo, layout) in [
            (AlgoKind::Im2win, Layout::Nhwc),
            (AlgoKind::Direct, Layout::Chwn8),
            (AlgoKind::Im2col, Layout::Nchw),
        ] {
            let mut last = f64::INFINITY;
            for eff in [0.02, 0.1, 0.3, 0.6, 0.95] {
                let mut profile = CalibrationProfile::new(25.0, 2);
                profile.set_series(algo, layout, eff, 1);
                let planner = Planner { profile: Some(profile), threads: 2, ..Planner::new() };
                let est = planner.estimate(algo, layout, &p, layout);
                assert!(
                    est < last,
                    "{algo} {layout} on {p}: eff {eff} gave {est}, not below {last}"
                );
                last = est;
            }
        }
    }
}

#[test]
fn fingerprint_change_invalidates_persisted_plans_end_to_end() {
    let dir = temp_dir("invalidate");
    let path = dir.join("plans.json");
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 5).unwrap();

    // 1. Analytic planning fills and persists the cache.
    let analytic = Planner::new();
    let mut cache = PlanCache::load(&path).unwrap();
    let a_plans = analytic.plan_model(&model, &mut cache).unwrap();
    assert_eq!(cache.profile_fingerprint(), "");
    cache.save().unwrap();

    // 2. A calibrated planner must not reuse analytic decisions: the
    //    fingerprint mismatch drops every entry and re-plans.
    let mut profile = CalibrationProfile::new(20.0, analytic.threads);
    profile.set_series(AlgoKind::Im2col, Layout::Nchw, 0.95, 4);
    profile.set_series(AlgoKind::Im2win, Layout::Nhwc, 0.05, 4);
    let calibrated = Planner { profile: Some(profile), ..Planner::new() };
    let mut cache2 = PlanCache::load(&path).unwrap();
    assert_eq!(cache2.len(), a_plans.len());
    let c_plans = calibrated.plan_model(&model, &mut cache2).unwrap();
    assert_eq!(cache2.hits(), 0, "stale analytic plans were reused");
    assert_eq!(cache2.misses(), c_plans.len());
    assert_eq!(cache2.profile_fingerprint(), calibrated.profile_fingerprint());
    cache2.save().unwrap();

    // 3. Same profile again: pure hits, identical plans.
    let mut cache3 = PlanCache::load(&path).unwrap();
    let again = calibrated.plan_model(&model, &mut cache3).unwrap();
    assert_eq!(again, c_plans);
    assert_eq!(cache3.hits(), c_plans.len());
    assert_eq!(cache3.misses(), 0);

    // 4. Going back to the analytic constants invalidates once more.
    let mut cache4 = PlanCache::load(&path).unwrap();
    analytic.plan_model(&model, &mut cache4).unwrap();
    assert_eq!(cache4.hits(), 0);
    assert_eq!(cache4.profile_fingerprint(), "");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_pack_persists_plans_for_the_whole_suite() {
    let dir = temp_dir("warmpack");
    let path = dir.join("plans.json");
    let profile = CalibrationProfile::fit(&conv12_upset(), 2).unwrap();
    let planner = Planner { profile: Some(profile), threads: 2, batch: 8, ..Planner::new() };
    let mut cache = PlanCache::load(&path).unwrap();
    cache.sync_profile(&planner.profile_fingerprint());
    let n = warm_pack(&planner, &mut cache);
    assert_eq!(n, TABLE1.len() * Layout::ALL.len());
    cache.save().unwrap();

    // A fresh process (same profile) finds every Table I decision warm.
    let mut warm = PlanCache::load(&path).unwrap();
    warm.sync_profile(&planner.profile_fingerprint());
    assert_eq!(warm.len(), n, "fingerprint sync must keep warm-packed plans");
    for layer in &TABLE1 {
        let p = layer.params(planner.batch);
        for prev in Layout::ALL {
            let plan = warm.get(&layer_key(&p, prev, planner.threads));
            assert!(plan.is_some(), "{}: missing warm plan", layer.name);
        }
    }
    assert_eq!(warm.misses(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fit_from_a_real_smoke_sweep_grounds_the_planner() {
    // End to end on real kernels: a tiny coordinator sweep → fit →
    // the profile covers every measured series and feeds estimates.
    let mut cfg = ExperimentConfig::paper_matrix(Scale::Smoke);
    cfg.layers = vec!["conv9".into()];
    let records = experiments::fig4(&cfg).unwrap();
    let threads = im2win::parallel::configured_threads();
    let profile = CalibrationProfile::fit(&records, threads).unwrap();
    assert!(profile.peak_gflops > 0.0);
    let best = records.iter().map(Record::gflops).fold(f64::MIN, f64::max);
    assert!((profile.peak_gflops - best).abs() < 1e-9);
    let p = by_name("conv9").unwrap().params(8);
    for r in &records {
        let algo = AlgoKind::parse(&r.algo).unwrap();
        let layout = Layout::parse(&r.layout).unwrap();
        let eff = profile.efficiency(algo, layout, &p);
        assert!(eff.is_some(), "{}: measured series missing from fit", r.series());
        assert!(eff.unwrap() > 0.0 && eff.unwrap() <= 1.0);
    }
    // The calibrated planner consults the fit (estimates move).
    let analytic = Planner { threads, batch: 8, ..Planner::new() };
    let calibrated = Planner { profile: Some(profile), ..analytic.clone() };
    let moved = records.iter().any(|r| {
        let algo = AlgoKind::parse(&r.algo).unwrap();
        let layout = Layout::parse(&r.layout).unwrap();
        analytic.estimate(algo, layout, &p, layout) != calibrated.estimate(algo, layout, &p, layout)
    });
    assert!(moved, "no estimate consulted the measured fit");
}
