//! Integration tests for the graph planner: end-to-end parity of the
//! mixed-layout execution against the single-layout engine and the
//! oracle model from every starting input layout, the DP ≤ greedy
//! guarantee over the whole zoo (strict on mixnet, the model built so
//! the greedy chain leaves money on the table), and whole-graph plan
//! persistence through the on-disk cache.

use im2win::conv::AlgoKind;
use im2win::engine::{calibrate::CalibrationProfile, Engine, PlanCache, Planner};
use im2win::model::{zoo, Model};
use im2win::prelude::*;

/// The mixnet trap is regime-sensitive: pin the cost model to the
/// parallelism and batch the geometry was designed for, so the plans
/// under test are identical on every runner.
fn pinned() -> Planner {
    Planner { threads: 4, batch: 8, ..Planner::new() }
}

fn temp_path(stem: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("im2win_graph_{}_{stem}", std::process::id()))
}

#[test]
fn graph_engine_matches_oracle_from_every_starting_layout() {
    // Acceptance: the graph-planned mixed-layout forward is parity-clean
    // no matter which layout the model (and its input) start in, stays
    // bit-identical across repeats, and allocates nothing once warm.
    for (i, &layout) in Layout::ALL.iter().enumerate() {
        let seed = 21 + i as u64;
        let x = Tensor4::random(Dims::new(2, 3, 40, 40), layout, 400 + i as u64);
        let expect =
            zoo::mixnet(layout, AlgoKind::Naive, seed).unwrap().forward(&x).unwrap();

        let mut cache = PlanCache::in_memory();
        let mut engine = Engine::plan_graph(
            zoo::mixnet(layout, AlgoKind::Naive, seed).unwrap(),
            &pinned(),
            &mut cache,
        )
        .unwrap();
        let y = engine.forward(&x).unwrap();
        assert!(
            expect.allclose(&y, 1e-3, 1e-4),
            "from {layout}: graph-planned forward diverges from oracle by {}",
            expect.max_abs_diff(&y)
        );

        let misses = engine.workspace().misses();
        let y2 = engine.forward(&x).unwrap();
        assert_eq!(y.data(), y2.data(), "from {layout}: repeat forward must be identical");
        assert_eq!(
            engine.workspace().misses(),
            misses,
            "from {layout}: warm forward allocated new scratch"
        );
    }
}

#[test]
fn graph_engine_matches_single_layout_engine() {
    // The mixed-layout plan and the greedy single-chain plan are
    // different execution strategies for the same function: their
    // outputs must agree with each other (and the oracle) on mixnet,
    // where the graph plan genuinely mixes layouts.
    let planner = pinned();
    let x = Tensor4::random(Dims::new(4, 3, 40, 40), Layout::Nchw, 77);
    let expect =
        zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 9).unwrap().forward(&x).unwrap();

    let mut cache = PlanCache::in_memory();
    let mut greedy = Engine::plan(
        zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 9).unwrap(),
        &planner,
        &mut cache,
    )
    .unwrap();
    let mut cache = PlanCache::in_memory();
    let mut graph = Engine::plan_graph(
        zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 9).unwrap(),
        &planner,
        &mut cache,
    )
    .unwrap();
    let gplan = graph.graph_plan().expect("graph engine carries its plan");
    assert!(gplan.distinct_layouts() > 1, "mixnet's optimum must mix layouts");

    let y_greedy = greedy.forward(&x).unwrap();
    let y_graph = graph.forward(&x).unwrap();
    assert!(expect.allclose(&y_greedy, 1e-3, 1e-4), "{}", expect.max_abs_diff(&y_greedy));
    assert!(expect.allclose(&y_graph, 1e-3, 1e-4), "{}", expect.max_abs_diff(&y_graph));
    assert!(
        y_greedy.allclose(&y_graph, 1e-3, 1e-4),
        "greedy and graph-planned forwards diverge by {}",
        y_greedy.max_abs_diff(&y_graph)
    );
}

#[test]
fn dp_total_never_exceeds_greedy_across_the_zoo() {
    // The greedy assignment is one feasible path through the lattice, so
    // the exact DP can never cost more under the shared cost model — on
    // any zoo model, from any starting layout. On mixnet the inequality
    // must be strict: that model exists to prove the greedy chain
    // suboptimal.
    let planner = pinned();
    let greedy_total = |model: &Model| -> f64 {
        let mut cache = PlanCache::in_memory();
        planner.plan_model(model, &mut cache).unwrap().iter().map(|p| p.est_s).sum()
    };
    for layout in Layout::ALL {
        let models = [
            zoo::tinynet(layout, AlgoKind::Naive, 1).unwrap(),
            zoo::tinynet_biased(layout, AlgoKind::Naive, 1).unwrap(),
            zoo::vgg_stack(layout, AlgoKind::Naive, 64, 1).unwrap(),
            zoo::mixnet(layout, AlgoKind::Naive, 1).unwrap(),
        ];
        for model in models {
            let mut cache = PlanCache::in_memory();
            let graph = planner.plan_graph(&model, &mut cache).unwrap();
            let greedy = greedy_total(&model);
            assert!(
                graph.total_s <= greedy + 1e-12,
                "{} from {layout}: dp {} > greedy {greedy}",
                model.name,
                graph.total_s
            );
        }
    }
    let mixnet = zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 1).unwrap();
    let mut cache = PlanCache::in_memory();
    let graph = planner.plan_graph(&mixnet, &mut cache).unwrap();
    let greedy = greedy_total(&mixnet);
    assert!(
        graph.total_s < greedy * (1.0 - 1e-6),
        "mixnet: dp {} must be strictly cheaper than greedy {greedy}",
        graph.total_s
    );
}

#[test]
fn second_process_run_hits_the_persisted_graph() {
    // Whole-graph entries round-trip through the on-disk cache: a fresh
    // load answers the DP from disk without re-solving.
    let path = temp_path("persist.json");
    std::fs::remove_file(&path).ok();
    let planner = pinned();
    let model = || zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 6).unwrap();

    let first;
    {
        let mut cache = PlanCache::load(&path).unwrap();
        first = planner.plan_graph(&model(), &mut cache).unwrap();
        assert_eq!(cache.graph_len(), 1);
        cache.save().unwrap();
    }
    {
        let mut cache = PlanCache::load(&path).unwrap();
        assert_eq!(cache.graph_len(), 1, "graph entry must survive the round trip");
        let again = planner.plan_graph(&model(), &mut cache).unwrap();
        assert_eq!(first, again, "persisted graph must be reused verbatim");
        assert_eq!(cache.misses(), 0, "a second run must not re-solve the DP");
        assert!(cache.hits() > 0);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn calibration_refit_invalidates_persisted_graphs() {
    // A planner under a different calibration profile must not reuse a
    // graph solved under the old cost model: sync_profile drops it and
    // the DP re-solves.
    let planner = pinned();
    let model = zoo::mixnet(Layout::Nchw, AlgoKind::Naive, 6).unwrap();
    let mut cache = PlanCache::in_memory();
    planner.plan_graph(&model, &mut cache).unwrap();
    assert_eq!(cache.graph_len(), 1);

    let mut profile = CalibrationProfile::new(50.0, planner.threads);
    profile.set_convert(Layout::Nchw, Layout::Chwn8, 35.0, 3);
    let calibrated = Planner { profile: Some(profile), ..pinned() };
    let graph = calibrated.plan_graph(&model, &mut cache).unwrap();
    assert_eq!(cache.graph_len(), 1, "stale graph must be dropped, fresh one stored");
    assert!(graph.total_s > 0.0 && graph.total_s.is_finite());
}
