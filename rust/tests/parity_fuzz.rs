//! Differential parity-fuzz harness.
//!
//! A seeded geometry sampler ([`im2win::testutil::random_problems`])
//! drives every algorithm × layout × epilogue cell of the prepacked
//! serving path against the naive oracle — at f32 exactly, and at each
//! reduced tier (f16/bf16/int8) against an *emulated* reference that
//! performs the tier's rounding/quantization in plain scalar code. The
//! emulated reference pins the implementation (same grid, same scales,
//! same dequant epilogue); a separate budget assertion pins the tier's
//! accuracy contract against the true f32 oracle.
//!
//! The suite is deterministic: `PARITY_FUZZ_SEED` (pinned in CI) selects
//! the geometry stream, and every panic message leads with the exact
//! environment line that reproduces the failing cell locally.
//!
//! Tolerance ladder:
//!   f32        1e-4 vs the oracle (accumulation order only)
//!   f16/bf16   1e-3 vs the emulated rounded reference,
//!              `F16_TOLERANCE`-scaled budget vs the true oracle
//!   int8       1e-3 vs the emulated quantized reference,
//!              `INT8_TOLERANCE`-scaled budget vs the true oracle

use im2win::conv::precision::{self, Precision};
use im2win::conv::winograd::winograd_ok;
use im2win::conv::{reference_conv, AlgoKind, ConvParams, Epilogue};
use im2win::engine::Workspace;
use im2win::prelude::*;
use im2win::testutil::{fuzz_seed, random_problems};

/// Default geometry-stream seed; CI exports `PARITY_FUZZ_SEED` with this
/// value so the matrix legs and a local repro run the identical suite.
const DEFAULT_SEED: u64 = 278;

/// The hot-path algorithms with reduced-precision kernels (the only ones
/// the planner offers sub-f32 tiers on).
const REDUCED_ALGOS: [AlgoKind; 2] = [AlgoKind::Im2win, AlgoKind::Im2col];

/// One repro prefix for every assertion in this file.
fn repro(seed: u64, i: usize, p: &ConvParams) -> String {
    format!(
        "repro: PARITY_FUZZ_SEED={seed} cargo test --test parity_fuzz  [problem #{i}: {p}]"
    )
}

/// NaN-poisoned output: an element the kernel fails to store is a loud
/// mismatch, never a lucky zero.
fn poisoned(p: &ConvParams, layout: Layout) -> Tensor4 {
    let mut out = Tensor4::zeros(p.output_dims(), layout);
    out.data_mut().fill(f32::NAN);
    out
}

fn max_abs(t: &Tensor4) -> f32 {
    t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// Every fuseable epilogue over one bias vector.
fn epilogues(bias: &[f32]) -> [Epilogue<'_>; 4] {
    [Epilogue::None, Epilogue::Relu, Epilogue::Bias(bias), Epilogue::BiasRelu(bias)]
}

/// Whether `algo` can run `p` at all (mirrors the planner's gates).
fn runnable(algo: AlgoKind, p: &ConvParams, layout: Layout) -> bool {
    algo.build().supports(layout)
        && (algo != AlgoKind::Depthwise || p.is_depthwise())
        && (algo != AlgoKind::Winograd || winograd_ok(p))
}

/// The f32 sweep: every sampled geometry × layout × algorithm × epilogue
/// through prepare + run_prepacked, on poisoned outputs with one recycled
/// workspace, vs the naive oracle at 1e-4.
#[test]
fn fuzz_f32_prepacked_parity_across_all_algorithms() {
    let seed = fuzz_seed(DEFAULT_SEED);
    let problems = random_problems(200, seed);
    let mut ws = Workspace::new();
    let mut cells = 0usize;
    for (i, p) in problems.iter().enumerate() {
        let bias: Vec<f32> = (0..p.c_out).map(|c| 0.2 * c as f32 - 0.4).collect();
        for layout in Layout::ALL {
            let x = Tensor4::random(p.input_dims(), layout, seed ^ (2 * i as u64));
            let f = Tensor4::random(p.filter_dims(), layout, seed ^ (2 * i as u64 + 1));
            let oracle = reference_conv(&x, &f, p, layout);
            for algo in AlgoKind::ALL {
                if !runnable(algo, p, layout) {
                    continue;
                }
                let a = algo.build();
                let packed = a
                    .prepare(&f, p, layout)
                    .unwrap_or_else(|e| panic!("{} {algo} {layout}: prepare: {e}", repro(seed, i, p)));
                for ep in epilogues(&bias) {
                    let mut expect = oracle.clone();
                    ep.apply_to(&mut expect);
                    let mut out = poisoned(p, layout);
                    a.run_prepacked(&x, &packed, p, &mut out, &mut ws, ep)
                        .unwrap_or_else(|e| {
                            panic!("{} {algo} {layout} {ep:?}: {e}", repro(seed, i, p))
                        });
                    // Winograd's own documented bound is looser than the
                    // exact-rearrangement algorithms'.
                    let tol = if algo == AlgoKind::Winograd { 1e-3 } else { 1e-4 };
                    assert!(
                        expect.allclose(&out, tol, tol),
                        "{} {algo} {layout} {ep:?}: max diff {}",
                        repro(seed, i, p),
                        expect.max_abs_diff(&out)
                    );
                    cells += 1;
                }
            }
        }
    }
    // The skip predicates must never silently hollow out the sweep.
    assert!(cells > 4000, "suite degenerated: only {cells} cells ran");
}

/// The reduced-tier sweep: dense geometries × 4 layouts × im2win/im2col
/// × f16/bf16/int8 × every epilogue, vs the emulated reference (tight)
/// and the true f32 oracle (tier budget).
#[test]
fn fuzz_reduced_tiers_match_emulated_reference_and_hold_budget() {
    let seed = fuzz_seed(DEFAULT_SEED);
    let problems: Vec<ConvParams> =
        random_problems(200, seed).into_iter().filter(|p| p.groups == 1).take(50).collect();
    assert_eq!(problems.len(), 50, "sampler stopped producing dense geometries");
    let mut ws = Workspace::new();
    for (i, p) in problems.iter().enumerate() {
        let bias: Vec<f32> = (0..p.c_out).map(|c| 0.15 * c as f32 - 0.3).collect();
        for layout in Layout::ALL {
            let x = Tensor4::random(p.input_dims(), layout, seed ^ (4 * i as u64));
            let f = Tensor4::random(p.filter_dims(), layout, seed ^ (4 * i as u64 + 1));
            let oracle = reference_conv(&x, &f, p, layout);
            for prec in [Precision::F16AccF32, Precision::Bf16AccF32, Precision::Int8] {
                // Emulated reference: the tier's exact conversion applied
                // in scalar code to the raw operands. Transforms are
                // copies, so converting before the lowering equals the
                // kernel's convert-after-lowering.
                let (base, combined) = if prec == Precision::Int8 {
                    let s_w = precision::filter_scales(&f, p);
                    let f_q = precision::quantized_filter(&f, p, &s_w);
                    let s_a = precision::activation_scale(x.data());
                    let mut x_q = x.clone();
                    precision::quantize_slice(x_q.data_mut(), s_a);
                    let combined: Vec<f32> = s_w.iter().map(|&w| w * s_a).collect();
                    (reference_conv(&x_q, &f_q, p, layout), Some(combined))
                } else {
                    let f_r = precision::rounded_tensor(&f, prec);
                    let mut x_r = x.clone();
                    precision::round_activations(x_r.data_mut(), prec);
                    (reference_conv(&x_r, &f_r, p, layout), None)
                };
                for algo in REDUCED_ALGOS {
                    let a = algo.build();
                    if !a.supports(layout) {
                        continue;
                    }
                    let packed =
                        a.prepare_with_precision(&f, p, layout, prec).unwrap_or_else(|e| {
                            panic!("{} {algo} {layout} {prec}: prepare: {e}", repro(seed, i, p))
                        });
                    for ep in epilogues(&bias) {
                        let mut expect = base.clone();
                        match &combined {
                            Some(scales) => ep.with_dequant(scales).apply_to(&mut expect),
                            None => ep.apply_to(&mut expect),
                        }
                        let mut out = poisoned(p, layout);
                        a.run_prepacked(&x, &packed, p, &mut out, &mut ws, ep)
                            .unwrap_or_else(|e| {
                                panic!("{} {algo} {layout} {prec} {ep:?}: {e}", repro(seed, i, p))
                            });
                        assert!(
                            expect.allclose(&out, 1e-3, 1e-3),
                            "{} {algo} {layout} {prec} {ep:?}: emulated-reference diff {}",
                            repro(seed, i, p),
                            expect.max_abs_diff(&out)
                        );
                        // Tier budget vs the true oracle, scaled by output
                        // magnitude (quantization error is relative to the
                        // tensor's dynamic range, not absolute).
                        if matches!(ep, Epilogue::None) {
                            let budget = prec.min_tolerance() * (1.0 + max_abs(&oracle));
                            let diff = oracle.max_abs_diff(&out);
                            assert!(
                                diff <= budget,
                                "{} {algo} {layout} {prec}: budget blown: {diff} > {budget}",
                                repro(seed, i, p)
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Geometry and algorithms outside the reduced hot path must reject
/// sub-f32 packs with a typed error — never a wrong-answer fallback.
#[test]
fn fuzz_reduced_tiers_are_rejected_off_the_hot_path() {
    let seed = fuzz_seed(DEFAULT_SEED);
    let problems = random_problems(200, seed);
    let grouped = problems.iter().find(|p| p.groups > 1).expect("sampler lost grouped coverage");
    let dense = problems.iter().find(|p| p.groups == 1).unwrap();
    for prec in [Precision::F16AccF32, Precision::Bf16AccF32, Precision::Int8] {
        // Hot-path algorithms refuse grouped geometry at reduced tiers.
        for algo in REDUCED_ALGOS {
            let a = algo.build();
            let f = Tensor4::random(grouped.filter_dims(), Layout::Nchw, 3);
            let e = a.prepare_with_precision(&f, grouped, Layout::Nchw, prec).unwrap_err();
            assert!(
                matches!(e, Error::UnsupportedPrecision(_)),
                "{algo} {prec} grouped: wrong error {e}"
            );
        }
        // Algorithms without reduced kernels refuse even dense geometry.
        for algo in [AlgoKind::Direct, AlgoKind::Mec, AlgoKind::Indirect, AlgoKind::Naive] {
            let a = algo.build();
            let f = Tensor4::random(dense.filter_dims(), Layout::Nhwc, 4);
            let e = a.prepare_with_precision(&f, dense, Layout::Nhwc, prec).unwrap_err();
            assert!(
                matches!(e, Error::UnsupportedPrecision(_)),
                "{algo} {prec}: wrong error {e}"
            );
        }
    }
    // F32 through the same entry point stays the plain prepare.
    let a = AlgoKind::Im2win.build();
    let f = Tensor4::random(dense.filter_dims(), Layout::Nchw, 5);
    let pack = a.prepare_with_precision(&f, dense, Layout::Nchw, Precision::F32).unwrap();
    assert_eq!(pack.precision(), Precision::F32);
}
