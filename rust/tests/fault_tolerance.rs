//! Fault-tolerance integration tests: plan-cache corruption recovery,
//! request deadlines, and — under `--features fault-inject` — the full
//! chaos suite: panic isolation with supervised respawn, restart-budget
//! exhaustion with routing-around, and ticket liveness on the async
//! front while faults fire and shutdown races a respawn.
//!
//! The feature-gated tests serialize on
//! [`im2win::engine::faultinject::test_lock`] because the fault
//! registry is process-global and the default test runner is parallel.

use im2win::conv::AlgoKind;
use im2win::engine::{
    AsyncConfig, AsyncServer, Engine, PlanCache, Planner, ShardConfig, ShardedServer,
};
use im2win::error::Error;
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;
use std::path::PathBuf;
use std::time::Duration;

fn tinynet_engine(threads: usize) -> Engine {
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let mut cache = PlanCache::in_memory();
    let planner = Planner { threads, ..Planner::new() };
    Engine::plan(model, &planner, &mut cache).unwrap()
}

fn image(seed: u64) -> Tensor4 {
    Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, seed)
}

fn small_cfg() -> ShardConfig {
    ShardConfig {
        max_batch: 4,
        threads_per_shard: 1,
        restart_backoff: Duration::ZERO,
        ..ShardConfig::default()
    }
}

/// A unique scratch path under the system temp dir (no external crates).
fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("im2win-ft-{}-{tag}.json", std::process::id()))
}

fn remove_quiet(p: &PathBuf) {
    let _ = std::fs::remove_file(p);
}

#[test]
fn corrupt_plan_cache_is_quarantined_and_serving_proceeds() {
    // Cross-test isolation: under fault-inject the CacheCorrupt probe
    // consults the global registry; hold the lock so a chaos test in
    // this binary cannot force a corruption verdict here.
    #[cfg(feature = "fault-inject")]
    let _guard = im2win::engine::faultinject::test_lock();

    let path = scratch("quarantine");
    let corrupt1 = {
        let mut n = path.as_os_str().to_os_string();
        n.push(".corrupt-1");
        PathBuf::from(n)
    };
    let corrupt2 = {
        let mut n = path.as_os_str().to_os_string();
        n.push(".corrupt-2");
        PathBuf::from(n)
    };
    remove_quiet(&path);
    remove_quiet(&corrupt1);
    remove_quiet(&corrupt2);

    // First boot against a garbage file: quarantined to `.corrupt-1`,
    // serving starts from an empty cache instead of crashing.
    std::fs::write(&path, b"{ this is not a plan cache").unwrap();
    let (mut cache, moved) = PlanCache::load_or_recover(&path);
    assert_eq!(moved.as_deref(), Some(corrupt1.as_path()));
    assert!(corrupt1.exists(), "corrupt file was not preserved for forensics");
    assert!(!path.exists(), "corrupt file left in place");
    assert!(cache.is_empty());

    // The recovered (empty) cache plans and persists normally.
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let planner = Planner { threads: 1, ..Planner::new() };
    let engine = Engine::plan(model, &planner, &mut cache).unwrap();
    cache.save().unwrap();
    assert!(path.exists());

    // Serving proceeds on the recovered plans.
    let server = ShardedServer::start(vec![engine], small_cfg());
    let rx = server.submit(image(7));
    assert!(rx.recv().unwrap().is_ok());
    let report = server.shutdown();
    assert_eq!(report.served(), 1);

    // A second corruption picks the next free quarantine number.
    std::fs::write(&path, b"also garbage").unwrap();
    let (cache, moved) = PlanCache::load_or_recover(&path);
    assert_eq!(moved.as_deref(), Some(corrupt2.as_path()));
    assert!(cache.is_empty());

    remove_quiet(&path);
    remove_quiet(&corrupt1);
    remove_quiet(&corrupt2);
}

#[test]
fn zero_ttl_and_default_config_reproduce_baseline_behavior() {
    // `--ttl-us 0` and no breaker must be byte-for-byte today's paths:
    // a zero TTL is stored as "no deadline", nothing expires, and the
    // async front reports no breaker at all.
    let server = ShardedServer::start(vec![tinynet_engine(1)], small_cfg());
    let x = image(11);
    let base = server.submit(x.clone()).recv().unwrap().unwrap();
    let zero = server
        .submit_with_deadline(x.clone(), Duration::ZERO)
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(base, zero, "zero-TTL submit diverged from the plain submit path");
    let report = server.shutdown();
    assert_eq!(report.deadline_expired(), 0);
    assert_eq!(report.worker_panics(), 0);
    assert_eq!(report.dead_shards(), 0);

    let server =
        AsyncServer::start(vec![tinynet_engine(1)], small_cfg(), AsyncConfig::default());
    assert!(server.breaker_stats().is_none(), "default config grew a breaker");
    let client = server.client();
    let t = client.try_submit(image(12)).expect("idle ring admits");
    assert!(t.wait().is_ok());
    let report = server.shutdown();
    assert!(report.breaker.is_none());
    assert_eq!(report.sharded.served(), 1);
}

#[test]
fn tiny_ttl_expires_requests_with_deadline_exceeded() {
    let server = ShardedServer::start(vec![tinynet_engine(1)], small_cfg());
    let rxs: Vec<_> = (0..6)
        .map(|i| server.submit_with_deadline(image(20 + i), Duration::from_nanos(1)))
        .collect();
    for rx in &rxs {
        match rx.recv().unwrap() {
            Err(Error::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    let report = server.shutdown();
    assert_eq!(report.deadline_expired(), 6);
    assert_eq!(report.served(), 0, "expired requests burned kernel time");
}

#[cfg(not(feature = "fault-inject"))]
#[test]
fn arming_faults_without_the_feature_is_a_config_error() {
    use im2win::engine::faultinject;
    // Parsing still works (the CLI surface is feature-independent) …
    assert!(faultinject::FaultSpec::parse("kernel_panic:nth=3").is_ok());
    // … but arming must refuse loudly instead of silently no-opping.
    match faultinject::arm_spec("kernel_panic:nth=3") {
        Err(Error::Config(msg)) => assert!(msg.contains("fault-inject"), "unhelpful: {msg}"),
        other => panic!("expected Config error without the feature, got {other:?}"),
    }
}

#[cfg(feature = "fault-inject")]
mod chaos {
    use super::*;
    use im2win::engine::faultinject::{self, test_lock};
    use im2win::engine::TrySubmitError;
    use std::time::Instant;

    #[test]
    fn injected_panic_is_isolated_and_respawned_results_are_identical() {
        let _guard = test_lock();
        faultinject::clear();
        faultinject::arm_spec("kernel_panic:nth=1").unwrap();

        // Unfaulted twin server: the post-respawn engine must produce
        // bit-identical inferences (same plans, rebuilt workspace).
        let twin = ShardedServer::start(vec![tinynet_engine(1)], small_cfg());
        let server = ShardedServer::start(vec![tinynet_engine(1)], small_cfg());

        // First batch panics; its request is answered WorkerFailed by
        // the supervisor, not lost and not a test-process crash.
        match server.submit(image(30)).recv().unwrap() {
            Err(Error::WorkerFailed(msg)) => {
                assert!(msg.contains("fault-injected"), "wrong epitaph: {msg}")
            }
            other => panic!("expected WorkerFailed from the panicked batch, got {other:?}"),
        }

        // Subsequent requests ride the respawned engine and match the
        // twin exactly.
        for i in 0..4u64 {
            let x = image(40 + i);
            let got = server.submit(x.clone()).recv().unwrap().unwrap();
            let want = twin.submit(x).recv().unwrap().unwrap();
            assert_eq!(got, want, "post-respawn inference diverged from unfaulted twin");
        }

        let report = server.shutdown();
        assert_eq!(report.worker_panics(), 1);
        assert_eq!(report.respawns(), 1);
        assert_eq!(report.dead_shards(), 0);
        assert_eq!(report.failed_answers(), 0, "no answers lost beyond the panicked batch");
        assert_eq!(report.served(), 4);
        let twin_report = twin.shutdown();
        assert_eq!(twin_report.worker_panics(), 0);
        faultinject::clear();
    }

    #[test]
    fn restart_budget_exhaustion_marks_shard_dead_and_routes_around() {
        let _guard = test_lock();
        faultinject::clear();
        // One probe ever fires; max_restarts 0 turns that single panic
        // into a dead shard. Shard 1 never sees a firing probe.
        faultinject::arm_spec("kernel_panic:nth=1").unwrap();
        let cfg = ShardConfig { max_restarts: 0, ..small_cfg() };
        let server = ShardedServer::start(vec![tinynet_engine(1), tinynet_engine(1)], cfg);

        match server.submit_to(0, image(50)).recv().unwrap() {
            Err(Error::WorkerFailed(_)) => {}
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // The dead flag is raised by the supervisor right after the
        // answer goes out; give it a bounded moment.
        let t0 = Instant::now();
        while !server.shard_is_dead(0) {
            assert!(t0.elapsed() < Duration::from_secs(5), "shard 0 never marked dead");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(!server.shard_is_dead(1));

        // Round-robin dispatch now routes around the corpse: every
        // subsequent request succeeds on shard 1.
        for i in 0..6u64 {
            let inf = server.submit(image(60 + i)).recv().unwrap();
            assert!(inf.is_ok(), "request routed into the dead shard: {inf:?}");
        }

        let report = server.shutdown();
        assert_eq!(report.dead_shards(), 1);
        assert_eq!(report.worker_panics(), 1);
        assert_eq!(report.respawns(), 0);
        assert_eq!(report.served(), 6);
        assert!(report.throughput() > 0.0, "no live throughput after routing around");
        faultinject::clear();
    }

    #[test]
    fn async_tickets_all_reach_terminal_answers_under_chaos_and_shutdown() {
        let _guard = test_lock();
        faultinject::clear();
        // Straggler batches plus a mid-stream panic, on a deliberately
        // small ring: the worst case for stranded tickets. Shutdown is
        // called while answers are still in flight, racing the respawn.
        faultinject::arm_spec("slow_batch:every=4,ms=10").unwrap();
        faultinject::arm_spec("kernel_panic:nth=3").unwrap();
        let acfg = AsyncConfig { queue_depth: 4, ..AsyncConfig::default() };
        let server = AsyncServer::start(
            vec![tinynet_engine(1), tinynet_engine(1)],
            small_cfg(),
            acfg,
        );
        let client = server.client();

        let mut tickets = Vec::new();
        let mut shed = 0usize;
        for i in 0..40u64 {
            let mut img = image(100 + i);
            loop {
                match client.try_submit(img) {
                    Ok(t) => {
                        tickets.push(t);
                        break;
                    }
                    Err(TrySubmitError::QueueFull(back)) => {
                        // Bounded retry, then shed: liveness is about
                        // admitted requests, not admission itself.
                        shed += 1;
                        if shed > 2000 {
                            drop(back);
                            break;
                        }
                        img = back;
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) => panic!("unexpected admission error: {e}"),
                }
            }
        }
        assert!(!tickets.is_empty(), "nothing was admitted");

        // Shut down with tickets still pending. Every admitted ticket
        // must still resolve to exactly one terminal answer — Ok,
        // WorkerFailed, or a shutdown-time Overloaded — never a hang.
        let admitted = tickets.len();
        let report = server.shutdown();
        let (mut ok, mut terminal_errors) = (0usize, 0usize);
        for mut t in tickets {
            match t.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(_)) => ok += 1,
                Some(Err(_)) => terminal_errors += 1,
                None => panic!("admitted ticket never answered (liveness violated)"),
            }
        }
        // Exactly one terminal answer per admitted ticket; most should
        // have been served despite the stragglers and the panic.
        assert_eq!(ok + terminal_errors, admitted);
        assert!(ok > 0, "chaos run served nothing at all");
        assert!(report.sharded.served() >= ok, "report undercounts served answers");
        faultinject::clear();
    }
}
