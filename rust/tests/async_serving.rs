//! Integration tests for the async non-blocking serving front
//! (`engine::async_front`): correctness through the rings, backpressure
//! under a full ring, shed-policy behavior, ticket timeouts, completion
//! latency accounting, drain-on-shutdown, and the zero-allocation
//! steady-state submit path.

use im2win::conv::AlgoKind;
use im2win::engine::{
    AsyncConfig, AsyncServer, Engine, PlanCache, Planner, ShardConfig, Shed, ShardedServer,
    TrySubmitError,
};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;
use std::time::Duration;

fn tinynet_engine(threads: usize) -> Engine {
    let model = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let mut cache = PlanCache::in_memory();
    let planner = Planner { threads, ..Planner::new() };
    Engine::plan(model, &planner, &mut cache).unwrap()
}

fn image(seed: u64) -> Tensor4 {
    Tensor4::random(Dims::new(1, 3, 32, 32), Layout::Nchw, seed)
}

fn small_cfg() -> ShardConfig {
    ShardConfig { max_batch: 4, threads_per_shard: 1, ..ShardConfig::default() }
}

#[test]
fn async_front_serves_correct_results() {
    let reference = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 21).unwrap();
    let server =
        AsyncServer::start(vec![tinynet_engine(1)], small_cfg(), AsyncConfig::default());
    let client = server.client();
    let images: Vec<Tensor4> = (0..12).map(|i| image(100 + i)).collect();
    let tickets: Vec<_> = images
        .iter()
        .map(|x| client.try_submit(x.clone()).expect("default depth admits 12 requests"))
        .collect();
    for (x, t) in images.iter().zip(tickets) {
        let inf = t.wait().unwrap();
        assert_eq!(inf.dims, Dims::new(1, 10, 1, 1));
        let expect = reference.forward(x).unwrap();
        let got = inf.to_tensor(Layout::Nchw);
        assert!(
            expect.allclose(&got, 1e-3, 1e-4),
            "async-served logits diverge: {}",
            expect.max_abs_diff(&got)
        );
    }
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 12);
    assert_eq!(report.shed, 0);
    assert!(report.sharded.throughput() > 0.0);
}

#[test]
fn full_ring_backpressures_with_queue_full_not_deadlock() {
    // queue_depth 2 and a single 1-thread shard: the submit loop outruns
    // the drain loop immediately, so Reject policy must surface
    // QueueFull — and hand the image back — rather than block or drop.
    let cfg = ShardConfig { max_batch: 1, threads_per_shard: 1, ..ShardConfig::default() };
    let server = AsyncServer::start(
        vec![tinynet_engine(1)],
        cfg,
        AsyncConfig { queue_depth: 2, shed: Shed::Reject, ..AsyncConfig::default() },
    );
    let client = server.client();
    let mut tickets = Vec::new();
    let mut queue_full = 0usize;
    let mut img = image(7);
    let mut attempts = 0usize;
    while tickets.len() < 32 {
        attempts += 1;
        assert!(attempts < 100_000, "submit loop wedged: backpressure never cleared");
        match client.try_submit(img) {
            Ok(t) => {
                tickets.push(t);
                img = image(7 + tickets.len() as u64);
            }
            Err(TrySubmitError::QueueFull(back)) => {
                queue_full += 1;
                img = back; // retry without a copy
                std::thread::sleep(Duration::from_micros(50));
            }
            Err(TrySubmitError::Overloaded(_)) => panic!("no breaker configured"),
            Err(TrySubmitError::Closed(_)) => panic!("server closed mid-test"),
        }
    }
    assert!(
        queue_full > 0,
        "a depth-2 ring fed faster than it drains must report QueueFull"
    );
    // Every admitted request still completes successfully.
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 32);
    assert_eq!(report.shed, 0, "Reject policy never evicts queued work");
}

#[test]
fn oldest_first_shed_evicts_queued_work_instead_of_refusing() {
    let cfg = ShardConfig { max_batch: 1, threads_per_shard: 1, ..ShardConfig::default() };
    let server = AsyncServer::start(
        vec![tinynet_engine(1)],
        cfg,
        AsyncConfig { queue_depth: 2, shed: Shed::OldestFirst, ..AsyncConfig::default() },
    );
    let client = server.client();
    // Under OldestFirst every submit is admitted — overload lands on the
    // oldest queued ticket as Error::Overloaded instead.
    let tickets: Vec<_> = (0..64)
        .map(|i| client.try_submit(image(i)).expect("OldestFirst always admits"))
        .collect();
    let mut ok = 0usize;
    let mut shed = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(_) => ok += 1,
            Err(Error::Overloaded(_)) => shed += 1,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(ok + shed, 64, "every admitted ticket must be answered");
    assert!(shed > 0, "a depth-2 ring fed 64 requests back-to-back must shed");
    assert!(ok > 0, "shedding must not starve the queue entirely");
    let report = server.shutdown();
    assert_eq!(report.shed, shed);
    assert_eq!(report.sharded.served(), ok);
}

#[test]
fn wait_timeout_expires_then_the_result_still_arrives() {
    let cfg = ShardConfig { max_batch: 2, threads_per_shard: 1, ..ShardConfig::default() };
    let server = AsyncServer::start(
        vec![tinynet_engine(1)],
        cfg,
        AsyncConfig { queue_depth: 256, shed: Shed::Reject, ..AsyncConfig::default() },
    );
    let client = server.client();
    let mut tickets: Vec<_> =
        (0..32).map(|i| client.try_submit(image(i)).expect("depth 256 admits 32")).collect();
    // The last-submitted request sits behind 31 others on one slow
    // shard: a 1 µs wait must expire, not block until completion.
    let mut last = tickets.pop().unwrap();
    if !last.is_done() {
        let early = last.wait_timeout(Duration::from_micros(1));
        assert!(early.is_none(), "1 µs wait behind a deep queue should expire");
    }
    // The expired wait left the request in flight; a real wait gets it.
    let inf = last.wait().unwrap();
    assert_eq!(inf.dims, Dims::new(1, 10, 1, 1));
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 32);
}

#[test]
fn try_wait_yields_the_result_exactly_once() {
    let server =
        AsyncServer::start(vec![tinynet_engine(1)], small_cfg(), AsyncConfig::default());
    let client = server.client();
    let mut t = client.try_submit(image(3)).unwrap();
    // Poll until done (bounded).
    let mut got = None;
    for _ in 0..100_000 {
        if let Some(r) = t.try_wait() {
            got = Some(r);
            break;
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    got.expect("poll loop should observe completion").unwrap();
    assert!(t.is_done());
    assert!(t.try_wait().is_none(), "a consumed ticket yields nothing further");
    server.shutdown();
}

#[test]
fn completion_latency_is_monotonic_and_matches_sync_semantics() {
    // Same workload through the sync sharded front and the async front:
    // both must answer everything, and the async report's percentiles
    // must be internally consistent — queue wait (admission → flush) is
    // a prefix of completion latency (admission → done), so its
    // percentiles can never exceed the completion percentiles.
    let cfg = ShardConfig {
        max_batch: 4,
        deadline: Duration::from_millis(1),
        threads_per_shard: 1,
        ..ShardConfig::default()
    };
    let sync = ShardedServer::start(vec![tinynet_engine(1)], cfg.clone());
    let rxs: Vec<_> = (0..24).map(|i| sync.submit(image(i))).collect();
    for rx in &rxs {
        rx.recv().unwrap().unwrap();
    }
    let sync_report = sync.shutdown();

    let server = AsyncServer::start(vec![tinynet_engine(1)], cfg, AsyncConfig::default());
    let client = server.client();
    let tickets: Vec<_> = (0..24).map(|i| client.try_submit(image(i)).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let report = server.shutdown();

    assert_eq!(report.sharded.served(), sync_report.served());
    for (which, s) in
        sync_report.shards.iter().chain(report.sharded.shards.iter()).enumerate()
    {
        assert!(s.p99_latency_s >= s.p50_latency_s, "shard {which}: p99 < p50");
        assert!(s.p99_queue_s >= s.p50_queue_s, "shard {which}: queue p99 < p50");
        assert!(
            s.p50_queue_s <= s.p50_latency_s && s.p99_queue_s <= s.p99_latency_s,
            "shard {which}: queue wait exceeds completion latency"
        );
        assert!(s.p50_latency_s > 0.0);
    }
    assert!(report.sharded.p99_latency_s() >= report.sharded.p50_latency_s());
    assert!(report.sharded.p99_queue_s() <= report.sharded.p99_latency_s());
}

#[test]
fn shutdown_drains_every_admitted_ticket() {
    let server = AsyncServer::start(
        vec![tinynet_engine(1), tinynet_engine(1)],
        small_cfg(),
        AsyncConfig { queue_depth: 64, shed: Shed::Reject, ..AsyncConfig::default() },
    );
    let client = server.client();
    let mut tickets: Vec<_> =
        (0..40).map(|i| client.try_submit(image(i)).expect("depth 64 admits 40")).collect();
    // Shut down with the queues still loaded: every admitted ticket must
    // be answered before shutdown returns.
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 40, "shutdown dropped admitted requests");
    for t in &mut tickets {
        let r = t.try_wait().expect("ticket unanswered after shutdown");
        r.expect("drained request should succeed");
    }
}

#[test]
fn submits_after_shutdown_are_refused_cleanly() {
    let server =
        AsyncServer::start(vec![tinynet_engine(1)], small_cfg(), AsyncConfig::default());
    let client = server.client();
    client.try_submit(image(1)).unwrap().wait().unwrap();
    server.shutdown();
    match client.try_submit(image(2)) {
        Err(TrySubmitError::Closed(img)) => assert_eq!(img.dims(), Dims::new(1, 3, 32, 32)),
        Err(TrySubmitError::QueueFull(_)) => panic!("closed front reported QueueFull"),
        Err(TrySubmitError::Overloaded(_)) => panic!("closed front reported Overloaded"),
        Ok(_) => panic!("closed front admitted a request"),
    }
}

#[test]
fn steady_state_submit_path_allocates_no_completion_slots() {
    let server = AsyncServer::start(
        vec![tinynet_engine(1)],
        small_cfg(),
        AsyncConfig { queue_depth: 16, shed: Shed::Reject, ..AsyncConfig::default() },
    );
    let client = server.client();
    // Sequential submit → wait keeps outstanding tickets at 1: the
    // primed freelist recycles one slot forever, so the submit path
    // performs zero allocations across 200 requests.
    for i in 0..200 {
        let mut img = image(i);
        let t = loop {
            match client.try_submit(img) {
                Ok(t) => break t,
                Err(TrySubmitError::QueueFull(back)) => {
                    img = back;
                    std::thread::yield_now();
                }
                Err(TrySubmitError::Overloaded(_)) => panic!("no breaker configured"),
                Err(TrySubmitError::Closed(_)) => panic!("server closed mid-test"),
            }
        };
        t.wait().unwrap();
    }
    assert_eq!(server.slot_allocs(), 0, "steady-state submits must not allocate slots");
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 200);
    assert_eq!(report.slot_allocs, 0);
    // The serve loop itself also reached allocation-free steady state.
    assert_eq!(report.sharded.shards[0].warm_misses, 0);
}

#[test]
fn least_loaded_dispatch_feeds_every_shard() {
    let cfg = ShardConfig {
        max_batch: 4,
        deadline: Duration::from_millis(2),
        threads_per_shard: 1,
        ..ShardConfig::default()
    };
    let server = AsyncServer::start(
        vec![tinynet_engine(1), tinynet_engine(1)],
        cfg,
        AsyncConfig::default(),
    );
    assert_eq!(server.shards(), 2);
    let client = server.client();
    assert_eq!(client.shards(), 2);
    let tickets: Vec<_> = (0..10).map(|i| client.try_submit(image(i)).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(client.queue_depth(0), 0);
    assert_eq!(client.queue_depth(1), 0);
    let report = server.shutdown();
    assert_eq!(report.sharded.served(), 10);
    assert!(
        report.sharded.shards.iter().all(|s| s.served > 0),
        "round-robin tiebreak should feed both idle shards: {:?}",
        report.sharded.shards.iter().map(|s| s.served).collect::<Vec<_>>()
    );
}
