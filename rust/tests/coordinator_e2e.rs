//! Integration: the coordinator end to end — config in, experiments run,
//! reports out — plus the model runner over the full algorithm matrix.

use im2win::config::{ExperimentConfig, Scale};
use im2win::conv::AlgoKind;
use im2win::coordinator::{experiments, format_table, summary, write_csv, write_json};
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;

fn smoke_cfg(layers: &[&str]) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_matrix(Scale::Smoke);
    cfg.layers = layers.iter().map(|s| s.to_string()).collect();
    cfg
}

#[test]
fn full_pipeline_config_to_reports() {
    let cfg = smoke_cfg(&["conv9", "conv12"]);
    // 1. correctness gate
    let verified = experiments::verify(&cfg).unwrap();
    assert_eq!(verified.len(), 20);
    // 2. measurements
    let records = experiments::fig4(&cfg).unwrap();
    assert_eq!(records.len(), 20);
    // 3. summaries render
    let table = format_table(&records, |r| format!("{:.2}", r.gflops()));
    assert!(table.contains("conv9") && table.contains("im2win_NHWC"));
    assert!(!summary::winners(&records).is_empty());
    // 4. reports round-trip through the filesystem
    let dir = std::env::temp_dir().join(format!("im2win_e2e_{}", std::process::id()));
    let csv = dir.join("fig4.csv");
    let json = dir.join("fig4.json");
    write_csv(&csv, &records).unwrap();
    write_json(&json, &records).unwrap();
    let text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(text.lines().count(), records.len() + 1);
    let parsed = im2win::config::json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(parsed.as_array().unwrap().len(), records.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_json_drives_the_matrix() {
    let text = r#"{
        "scale": "smoke",
        "layers": ["conv12"],
        "cells": [
            {"algo": "im2win", "layout": "nhwc"},
            {"algo": "im2win", "layout": "chwn8"}
        ]
    }"#;
    let cfg = ExperimentConfig::from_json(text).unwrap();
    let records = experiments::fig4(&cfg).unwrap();
    assert_eq!(records.len(), 2);
    assert!(records.iter().all(|r| r.layer == "conv12" && r.algo == "im2win"));
}

#[test]
fn memory_invariants_across_more_layers() {
    // Fig. 5 ordering on the paper's memory-headline layer (conv5, 5x5
    // filter) at its REAL spatial size: the ordering direct < im2win <
    // im2col is a statement about transform buffers, which only dominate
    // once H_o x W_o is non-trivial (at /8-scaled dims the 256x96x5x5
    // filter copy dwarfs everything and the comparison is meaningless).
    use im2win::config::Cell;
    use im2win::coordinator::layers::by_name;
    let layer = by_name("conv5").unwrap();
    let (batch, div) = (4, 1);
    let get = |algo: AlgoKind, layout: Layout| {
        experiments::measure_memory(layer, Cell { algo, layout }, batch, div).unwrap()
    };
    for layout in [Layout::Nchw, Layout::Nhwc] {
        let d = get(AlgoKind::Direct, layout);
        let w = get(AlgoKind::Im2win, layout);
        let c = get(AlgoKind::Im2col, layout);
        assert!(d <= w, "{layout}: direct {d} > im2win {w}");
        assert!(w <= c, "{layout}: im2win {w} > im2col {c}");
        // paper: im2win uses ~24% of im2col's memory on conv5.
        let ratio = w as f64 / c as f64;
        assert!(ratio < 0.6, "{layout}: im2win/im2col = {ratio}");
    }
}

#[test]
fn model_runner_full_matrix_agrees() {
    let x = Tensor4::random(Dims::new(2, 3, 32, 32), Layout::Nchw, 77);
    let expect = zoo::tinynet(Layout::Nchw, AlgoKind::Naive, 3)
        .unwrap()
        .forward(&x)
        .unwrap();
    for algo in AlgoKind::BENCHED {
        for layout in Layout::ALL {
            let m = zoo::tinynet(layout, algo, 3).unwrap();
            let y = m.forward(&x).unwrap();
            assert!(
                expect.allclose(&y, 1e-3, 1e-3),
                "{algo} {layout}: {}",
                expect.max_abs_diff(&y)
            );
        }
    }
}

#[test]
fn batch_scaling_smoke_covers_all_figures() {
    let cfg = smoke_cfg(&["conv12"]);
    for (algo, figs) in [
        (AlgoKind::Direct, ["fig6", "fig7", "fig8", "fig9"]),
        (AlgoKind::Im2win, ["fig10", "fig11", "fig12", "fig13"]),
    ] {
        let records = experiments::batch_scaling(&cfg, algo).unwrap();
        for fig in figs {
            assert!(
                records.iter().any(|r| r.experiment == fig),
                "{algo}: missing {fig}"
            );
        }
        // Every record has positive throughput.
        assert!(records.iter().all(|r| r.gflops() > 0.0));
    }
}
