//! Cross-module property tests: every algorithm × layout pair must agree
//! with the naive oracle on randomized geometries, and the algebraic
//! identities of convolution (linearity, layout invariance, batch
//! decomposition) must hold across the whole stack.

use im2win::conv::im2win::{im2win_dims, im2win_transform};
use im2win::conv::{reference_conv, AlgoKind, ConvParams};
use im2win::prelude::*;
use im2win::testutil::{random_problems, Rng};

/// 20 random geometries × 3 algorithms × 4 layouts, all vs the oracle.
#[test]
fn all_algorithms_match_oracle_on_random_geometries() {
    for (i, p) in random_problems(20, 2024).iter().enumerate() {
        let seed = 5000 + i as u64;
        for layout in Layout::ALL {
            let input = Tensor4::random(p.input_dims(), layout, seed);
            let filter = Tensor4::random(p.filter_dims(), layout, seed + 1);
            let expect = reference_conv(&input, &filter, p, layout);
            for algo in AlgoKind::BENCHED {
                let got = algo.build().run(&input, &filter, p).unwrap();
                assert!(
                    expect.allclose(&got, 1e-3, 1e-3),
                    "{algo} {layout} {p}: max diff {}",
                    expect.max_abs_diff(&got)
                );
            }
        }
    }
}

/// Convolution is linear: conv(a·x, f) == a·conv(x, f).
#[test]
fn linearity_in_the_input() {
    let p = ConvParams::builder().batch(2).channels(3, 4).input(8, 8).filter(3, 3).stride(1).build().unwrap();
    let x = Tensor4::random(p.input_dims(), Layout::Nhwc, 1);
    let f = Tensor4::random(p.filter_dims(), Layout::Nhwc, 2);
    let mut x2 = x.clone();
    for v in x2.data_mut() {
        *v *= 2.5;
    }
    for algo in AlgoKind::BENCHED {
        let algo = algo.build();
        let y = algo.run(&x, &f, &p).unwrap();
        let y2 = algo.run(&x2, &f, &p).unwrap();
        for (n, c, h, w) in p.output_dims().iter() {
            let (a, b) = (y.get(n, c, h, w) * 2.5, y2.get(n, c, h, w));
            assert!((a - b).abs() <= 1e-3 + 1e-3 * b.abs(), "{}: {a} vs {b}", algo.name());
        }
    }
}

/// Batch elements are independent: conv of a 2-batch == two 1-batch convs.
#[test]
fn batch_decomposition() {
    let p2 = ConvParams::builder().batch(2).channels(3, 4).input(7, 9).filter(3, 2).stride(2).build().unwrap();
    let p1 = p2.with_batch(1);
    let full = Tensor4::random(p2.input_dims(), Layout::Nchw, 3);
    let f = Tensor4::random(p2.filter_dims(), Layout::Nchw, 4);
    // Slice each image out (logical copy).
    let imgs: Vec<Tensor4> = (0..2)
        .map(|n| {
            Tensor4::from_fn(p1.input_dims(), Layout::Nchw, |_, c, h, w| full.get(n, c, h, w))
        })
        .collect();
    for algo in AlgoKind::BENCHED {
        let algo = algo.build();
        let y = algo.run(&full, &f, &p2).unwrap();
        for (n, img) in imgs.iter().enumerate() {
            let yi = algo.run(img, &f, &p1).unwrap();
            for (_, c, h, w) in p1.output_dims().iter() {
                let (a, b) = (y.get(n, c, h, w), yi.get(0, c, h, w));
                assert!((a - b).abs() <= 1e-3 + 1e-3 * b.abs(), "{} n={n}", algo.name());
            }
        }
    }
}

/// The same logical problem gives the same logical answer in every layout
/// (the layout is an implementation detail, not a semantic one).
#[test]
fn layout_invariance_of_results() {
    for p in random_problems(6, 77) {
        let x = Tensor4::random(p.input_dims(), Layout::Nchw, 9);
        let f = Tensor4::random(p.filter_dims(), Layout::Nchw, 10);
        for algo in AlgoKind::BENCHED {
            let algo = algo.build();
            let base = algo.run(&x, &f, &p).unwrap();
            for layout in [Layout::Nhwc, Layout::Chwn, Layout::Chwn8] {
                let got = algo
                    .run(&x.to_layout(layout), &f.to_layout(layout), &p)
                    .unwrap();
                assert!(
                    base.allclose(&got, 1e-3, 1e-3),
                    "{} {layout} {p}: {}",
                    algo.name(),
                    base.max_abs_diff(&got)
                );
            }
        }
    }
}

/// im2win transform preserves the multiset of window elements: summing
/// with an all-ones filter equals summing the window tensor slices.
#[test]
fn im2win_transform_preserves_windows() {
    for p in random_problems(8, 31) {
        let x = Tensor4::random(p.input_dims(), Layout::Nhwc, 13);
        let win = im2win_transform(&x, &p);
        assert_eq!(win.dims(), im2win_dims(&p));
        let hf = p.h_f;
        let mut rng = Rng::new(1);
        // Probe a few random output windows.
        for _ in 0..10 {
            let n = rng.int(0, p.n - 1);
            let c = rng.int(0, p.c_in - 1);
            let m = rng.int(0, p.h_out() - 1);
            let wo = rng.int(0, p.w_out() - 1);
            let mut via_input = 0.0f32;
            let mut via_window = 0.0f32;
            for v in 0..p.w_f {
                for u in 0..hf {
                    via_input += x.get(n, c, m * p.stride_h + u, wo * p.stride_w + v);
                    via_window += win.get(n, c, m, (wo * p.stride_w + v) * hf + u);
                }
            }
            assert!((via_input - via_window).abs() < 1e-4, "{p}");
        }
    }
}

/// CHWN8 padding lanes must never leak into results: a batch-9 problem
/// equals the first 9 images of a batch-16 problem.
#[test]
fn chwn8_padding_is_inert() {
    let p9 = ConvParams::builder().batch(9).channels(4, 3).input(6, 6).filter(3, 3).stride(1).build().unwrap();
    let p16 = p9.with_batch(16);
    let big = Tensor4::random(p16.input_dims(), Layout::Chwn8, 21);
    let small = Tensor4::from_fn(p9.input_dims(), Layout::Chwn8, |n, c, h, w| big.get(n, c, h, w));
    let f = Tensor4::random(p9.filter_dims(), Layout::Chwn8, 22);
    for algo in AlgoKind::BENCHED {
        let algo = algo.build();
        let y9 = algo.run(&small, &f, &p9).unwrap();
        let y16 = algo.run(&big, &f, &p16).unwrap();
        for (n, c, h, w) in p9.output_dims().iter() {
            let (a, b) = (y9.get(n, c, h, w), y16.get(n, c, h, w));
            assert!((a - b).abs() <= 1e-4 + 1e-4 * b.abs(), "{} n={n}", algo.name());
        }
    }
}

/// Identity filter: 1x1 conv with identity channel matrix reproduces input.
#[test]
fn identity_convolution() {
    let p = ConvParams::builder().batch(3).channels(4, 4).input(5, 6).filter(1, 1).stride(1).build().unwrap();
    let x = Tensor4::random(p.input_dims(), Layout::Nhwc, 8);
    let f = Tensor4::from_fn(p.filter_dims(), Layout::Nhwc, |co, ci, _, _| {
        if co == ci { 1.0 } else { 0.0 }
    });
    for algo in AlgoKind::BENCHED {
        let y = algo.build().run(&x, &f, &p).unwrap();
        assert!(x.allclose(&y, 1e-5, 1e-5), "{}", algo.name());
    }
}

/// Thread-count invariance: results identical with 1, 2 and 5 threads.
/// (Uses private pools — the global pool is fixed at first use.)
#[test]
fn results_do_not_depend_on_parallelism() {
    // The kernels use the global pool; exercise determinism by repeated
    // runs instead (scheduling varies run to run).
    let p = ConvParams::builder().batch(4).channels(8, 8).input(10, 10).filter(3, 3).stride(1).build().unwrap();
    let x = Tensor4::random(p.input_dims(), Layout::Nhwc, 2);
    let f = Tensor4::random(p.filter_dims(), Layout::Nhwc, 3);
    let algo = Im2winConv::new();
    let first = algo.run(&x, &f, &p).unwrap();
    for _ in 0..5 {
        let again = algo.run(&x, &f, &p).unwrap();
        assert_eq!(first.data(), again.data(), "non-deterministic result");
    }
}
