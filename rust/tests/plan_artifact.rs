//! Cross-use rejection matrix for [`PlanArtifact::validate`]: an artifact
//! prepared for one (algorithm, layout, geometry) must refuse every other
//! algorithm, every other layout, and every geometry it was not keyed on —
//! for **all** [`AlgoKind`] arms, the indirect and Winograd families
//! included. Batch is explicitly excluded from the key: every artifact is
//! batch-agnostic by contract.

use im2win::conv::{AlgoKind, ConvParams, PlanArtifact};
use im2win::engine::Workspace;
use im2win::prelude::*;

/// A geometry each algorithm can actually prepare for: the depthwise
/// specialist needs depthwise channels; everything else (Winograd
/// included) is happy with a dense 3×3 stride-1 layer.
fn geometry_for(algo: AlgoKind) -> ConvParams {
    match algo {
        AlgoKind::Depthwise => ConvParams::builder()
            .batch(2)
            .channels(8, 8)
            .input(9, 9)
            .filter(3, 3)
            .pad(1)
            .groups(8)
            .build()
            .unwrap(),
        _ => ConvParams::builder()
            .batch(2)
            .channels(4, 6)
            .input(9, 9)
            .filter(3, 3)
            .build()
            .unwrap(),
    }
}

/// Same geometry with different channel extents — the filter dims change,
/// which every artifact (geometry-keyed or not) must reject.
fn different_filter(algo: AlgoKind) -> ConvParams {
    match algo {
        AlgoKind::Depthwise => ConvParams::builder()
            .batch(2)
            .channels(16, 16)
            .input(9, 9)
            .filter(3, 3)
            .pad(1)
            .groups(16)
            .build()
            .unwrap(),
        _ => ConvParams::builder()
            .batch(2)
            .channels(8, 6)
            .input(9, 9)
            .filter(3, 3)
            .build()
            .unwrap(),
    }
}

/// Same filter, different input spatial extent — only geometry-keyed
/// artifacts (indirect offsets, Winograd tiles) depend on this.
fn different_spatial(algo: AlgoKind) -> ConvParams {
    match algo {
        AlgoKind::Depthwise => ConvParams::builder()
            .batch(2)
            .channels(8, 8)
            .input(11, 9)
            .filter(3, 3)
            .pad(1)
            .groups(8)
            .build()
            .unwrap(),
        _ => ConvParams::builder()
            .batch(2)
            .channels(4, 6)
            .input(11, 9)
            .filter(3, 3)
            .build()
            .unwrap(),
    }
}

#[test]
fn validate_rejects_every_cross_algo_layout_and_geometry_mismatch() {
    for algo in AlgoKind::ALL {
        let algorithm = algo.build();
        let p = geometry_for(algo);
        for layout in Layout::ALL {
            if !algorithm.supports(layout) {
                continue;
            }
            let filter = Tensor4::random(p.filter_dims(), layout, 11);
            let art: PlanArtifact = algorithm
                .prepare(&filter, &p, layout)
                .unwrap_or_else(|e| panic!("{algo} {layout}: prepare failed: {e}"));
            assert_eq!(art.algo(), algo.name());
            assert_eq!(art.layout(), layout);
            assert!(art.storage_bytes() > 0, "{algo} {layout}: empty artifact");

            // The matching triple is accepted, at any batch size.
            art.validate(algo.name(), &p, layout)
                .unwrap_or_else(|e| panic!("{algo} {layout}: rejected its own key: {e}"));
            art.validate(algo.name(), &p.with_batch(7), layout)
                .unwrap_or_else(|e| panic!("{algo} {layout}: not batch-agnostic: {e}"));

            // Every *other* algorithm name is rejected.
            for other in AlgoKind::ALL {
                if other.name() == algo.name() {
                    continue;
                }
                assert!(
                    art.validate(other.name(), &p, layout).is_err(),
                    "{algo} {layout}: artifact accepted algorithm {other}"
                );
            }

            // Every other layout is rejected.
            for other in Layout::ALL {
                if other == layout {
                    continue;
                }
                assert!(
                    art.validate(algo.name(), &p, other).is_err(),
                    "{algo} {layout}: artifact accepted layout {other}"
                );
            }

            // A geometry with different filter dims is always rejected.
            assert!(
                art.validate(algo.name(), &different_filter(algo), layout).is_err(),
                "{algo} {layout}: artifact accepted a different filter shape"
            );

            // Input-geometry changes split by keying: the indirect and
            // Winograd artifacts pin the full geometry; plain filter
            // packs are geometry-agnostic by design.
            let keyed = matches!(algo, AlgoKind::Indirect | AlgoKind::Winograd);
            assert_eq!(
                art.geometry().is_some(),
                keyed,
                "{algo} {layout}: unexpected geometry keying"
            );
            let moved = different_spatial(algo);
            if keyed {
                assert!(
                    art.validate(algo.name(), &moved, layout).is_err(),
                    "{algo} {layout}: geometry-keyed artifact accepted another spatial extent"
                );
            } else {
                art.validate(algo.name(), &moved, layout).unwrap_or_else(|e| {
                    panic!("{algo} {layout}: filter pack wrongly pinned to spatial extent: {e}")
                });
            }
        }
    }
}

/// The rejection must hold end to end, not just in `validate`: handing a
/// prepared artifact to the wrong algorithm's `run_prepacked` fails
/// before any kernel touches the output.
#[test]
fn run_prepacked_refuses_foreign_artifacts() {
    let p = geometry_for(AlgoKind::Direct);
    let mut ws = Workspace::new();
    let layout = Layout::Nhwc;
    let filter = Tensor4::random(p.filter_dims(), layout, 3);
    let input = Tensor4::random(p.input_dims(), layout, 4);
    let mut out = Tensor4::zeros(p.output_dims(), layout);
    for owner in AlgoKind::ALL {
        // The depthwise specialist refuses to prepare for dense geometry —
        // there is no artifact to cross-use in that case.
        let art = match owner.build().prepare(&filter, &p, layout) {
            Ok(art) => art,
            Err(_) => continue,
        };
        for runner in AlgoKind::ALL {
            if runner.name() == owner.name() {
                continue;
            }
            let algorithm = runner.build();
            if !algorithm.supports(layout) {
                continue;
            }
            assert!(
                algorithm
                    .run_prepacked(&input, &art, &p, &mut out, &mut ws, Epilogue::None)
                    .is_err(),
                "{runner} ran on {owner}'s artifact"
            );
        }
    }
}
