//! Fused prepacked-filter + epilogue parity tests.
//!
//! The contract under test: for every algorithm with a fused path
//! (im2win, direct, im2col, MEC) on every layout it supports,
//! `prepare` + `run_prepacked(.., epilogue)` must match the unfused
//! reference `conv → +bias → ReLU` within 1e-4 — including recycled
//! (stale) workspace scratch, NaN-poisoned output storage, CHWN8
//! batch-padding invariants, and pack/run mismatch rejection.

use im2win::conv::{reference_conv, AlgoKind, Epilogue};
use im2win::engine::Workspace;
use im2win::prelude::*;
use im2win::tensor::Dims;

/// Unfused reference: reference_conv, then bias and ReLU as separate
/// logical passes.
fn reference_with_epilogue(
    input: &Tensor4,
    filter: &Tensor4,
    p: &ConvParams,
    layout: Layout,
    bias: Option<&[f32]>,
    relu: bool,
) -> Tensor4 {
    let mut out = reference_conv(input, filter, p, layout);
    for (n, c, h, w) in out.dims().iter() {
        let mut v = out.get(n, c, h, w);
        if let Some(b) = bias {
            v += b[c];
        }
        if relu {
            v = v.max(0.0);
        }
        out.set(n, c, h, w, v);
    }
    out
}

fn epilogue_for(bias: Option<&[f32]>, relu: bool) -> Epilogue<'_> {
    match (bias, relu) {
        (None, false) => Epilogue::None,
        (None, true) => Epilogue::Relu,
        (Some(b), false) => Epilogue::Bias(b),
        (Some(b), true) => Epilogue::BiasRelu(b),
    }
}

const FUSED_ALGOS: [AlgoKind; 4] =
    [AlgoKind::Im2win, AlgoKind::Direct, AlgoKind::Im2col, AlgoKind::Mec];

#[test]
fn fused_matches_unfused_reference_all_layouts() {
    // Two geometries: n=5/co=7 exercises the CHWN8 partial batch block
    // and every kernel's channel tail; the second exercises vector batch
    // lanes (n=10), strides and a rectangular filter.
    let problems = [
        ConvParams::builder().batch(5).channels(6, 7).input(12, 12).filter(3, 3).stride(1).build().unwrap(),
        ConvParams::builder().batch(10).channels(8, 4).input(11, 9).filter(3, 2).stride_hw(2, 1).build().unwrap(),
    ];
    for (pi, p) in problems.iter().enumerate() {
        let bias: Vec<f32> = (0..p.c_out).map(|c| (c as f32) * 0.3 - 0.8).collect();
        for algo in FUSED_ALGOS {
            let a = algo.build();
            for layout in Layout::ALL {
                if !a.supports(layout) {
                    continue;
                }
                let x = Tensor4::random(p.input_dims(), layout, 40 + pi as u64);
                let f = Tensor4::random(p.filter_dims(), layout, 50 + pi as u64);
                let packed = a.prepare(&f, p, layout).unwrap();
                let mut ws = Workspace::new();
                for relu in [false, true] {
                    for b in [None, Some(bias.as_slice())] {
                        let expect = reference_with_epilogue(&x, &f, p, layout, b, relu);
                        // Poisoned output: the fused path must fully
                        // define every storage element it leaves visible.
                        let mut out = Tensor4::zeros(p.output_dims(), layout);
                        out.data_mut().fill(f32::NAN);
                        a.run_prepacked(&x, &packed, p, &mut out, &mut ws, epilogue_for(b, relu))
                            .unwrap();
                        assert!(
                            out.data().iter().all(|v| v.is_finite()),
                            "{algo} {layout} relu={relu} bias={}: NaN survived",
                            b.is_some()
                        );
                        assert!(
                            expect.allclose(&out, 1e-4, 1e-4),
                            "{algo} {layout} relu={relu} bias={}: max diff {}",
                            b.is_some(),
                            expect.max_abs_diff(&out)
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn repeated_prepacked_runs_reuse_scratch_and_stay_identical() {
    // Same workspace across calls: stale window tensors / lowered
    // matrices must be fully overwritten, results bit-identical.
    let p = ConvParams::builder().batch(4).channels(5, 6).input(10, 10).filter(3, 3).stride(1).build().unwrap();
    let bias: Vec<f32> = (0..p.c_out).map(|c| 0.4 - c as f32 * 0.15).collect();
    for algo in FUSED_ALGOS {
        let a = algo.build();
        for layout in Layout::ALL {
            if !a.supports(layout) {
                continue;
            }
            let x = Tensor4::random(p.input_dims(), layout, 91);
            let f = Tensor4::random(p.filter_dims(), layout, 92);
            let packed = a.prepare(&f, &p, layout).unwrap();
            let mut ws = Workspace::new();
            let mut first = Tensor4::zeros(p.output_dims(), layout);
            a.run_prepacked(&x, &packed, &p, &mut first, &mut ws, Epilogue::BiasRelu(&bias))
                .unwrap();
            let misses = ws.misses();
            for _ in 0..3 {
                let mut again = Tensor4::zeros(p.output_dims(), layout);
                a.run_prepacked(&x, &packed, &p, &mut again, &mut ws, Epilogue::BiasRelu(&bias))
                    .unwrap();
                assert_eq!(first.data(), again.data(), "{algo} {layout}: nondeterministic");
            }
            assert_eq!(ws.misses(), misses, "{algo} {layout}: warm runs must not allocate");
        }
    }
}

#[test]
fn chwn8_padding_lanes_stay_zero_under_fused_bias_relu() {
    // n=5 < 8: one partial batch block whose lanes 5..8 are padding. A
    // strictly positive bias would leave max(bias, 0) > 0 there if the
    // kernels did not mask their epilogued stores.
    let p = ConvParams::builder().batch(5).channels(4, 6).input(8, 8).filter(3, 3).stride(1).build().unwrap();
    let bias = vec![0.5f32; p.c_out];
    for algo in FUSED_ALGOS {
        let a = algo.build();
        if !a.supports(Layout::Chwn8) {
            continue; // MEC is NHWC-only
        }
        let x = Tensor4::random(p.input_dims(), Layout::Chwn8, 61);
        let f = Tensor4::random(p.filter_dims(), Layout::Chwn8, 62);
        let packed = a.prepare(&f, &p, Layout::Chwn8).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor4::zeros(p.output_dims(), Layout::Chwn8);
        a.run_prepacked(&x, &packed, &p, &mut out, &mut ws, Epilogue::BiasRelu(&bias)).unwrap();
        // Storage is [N/8=1][Co][Ho][Wo][8]: every 8-chunk's lanes 5..8
        // are batch padding.
        for (i, chunk) in out.data().chunks_exact(8).enumerate() {
            assert!(
                chunk[5..].iter().all(|&v| v == 0.0),
                "{algo}: padding lane disturbed in chunk {i}: {:?}",
                &chunk[5..]
            );
        }
        // ...and the valid lanes still match the reference.
        let expect =
            reference_with_epilogue(&x, &f, &p, Layout::Chwn8, Some(&bias), true);
        assert!(expect.allclose(&out, 1e-4, 1e-4), "{algo}: {}", expect.max_abs_diff(&out));
    }
}

#[test]
fn mismatched_packs_are_rejected() {
    let p = ConvParams::builder().batch(2).channels(3, 4).input(8, 8).filter(3, 3).stride(1).build().unwrap();
    let layout = Layout::Nhwc;
    let x = Tensor4::random(p.input_dims(), layout, 71);
    let f = Tensor4::random(p.filter_dims(), layout, 72);
    let im2win = AlgoKind::Im2win.build();
    let direct = AlgoKind::Direct.build();
    let pack = im2win.prepare(&f, &p, layout).unwrap();
    assert_eq!(pack.algo(), "im2win");
    assert_eq!(pack.layout(), layout);
    assert_eq!(pack.filter_dims(), Dims::new(4, 3, 3, 3));
    assert!(pack.storage_bytes() > 0);
    let mut ws = Workspace::new();
    let mut out = Tensor4::zeros(p.output_dims(), layout);
    // Wrong algorithm for the pack.
    assert!(direct
        .run_prepacked(&x, &pack, &p, &mut out, &mut ws, Epilogue::None)
        .is_err());
    // Wrong layout: pack was prepared for NHWC.
    let x_nchw = x.to_layout(Layout::Nchw);
    let mut out_nchw = Tensor4::zeros(p.output_dims(), Layout::Nchw);
    assert!(im2win
        .run_prepacked(&x_nchw, &pack, &p, &mut out_nchw, &mut ws, Epilogue::None)
        .is_err());
    // Wrong geometry.
    let p2 = ConvParams::builder().batch(2).channels(3, 5).input(8, 8).filter(3, 3).stride(1).build().unwrap();
    let mut out2 = Tensor4::zeros(p2.output_dims(), layout);
    assert!(im2win
        .run_prepacked(&x, &pack, &p2, &mut out2, &mut ws, Epilogue::None)
        .is_err());
    // Bias length must match C_o.
    let short = [1.0f32; 3];
    assert!(im2win
        .run_prepacked(&x, &pack, &p, &mut out, &mut ws, Epilogue::Bias(&short))
        .is_err());
    // The happy path still works after all those rejections.
    im2win.run_prepacked(&x, &pack, &p, &mut out, &mut ws, Epilogue::None).unwrap();
    let expect = reference_conv(&x, &f, &p, layout);
    assert!(expect.allclose(&out, 1e-4, 1e-4));
}

#[test]
fn default_prepacked_path_covers_naive() {
    // Algorithms without a fused override (now just naive — MEC gained a
    // fused per-row-GEMM path) run through the default
    // prepare/run_prepacked: tensor-pack + unfused epilogue pass.
    let p = ConvParams::builder().batch(3).channels(4, 5).input(9, 9).filter(3, 3).stride(1).build().unwrap();
    let bias: Vec<f32> = (0..p.c_out).map(|c| c as f32 * 0.2 - 0.3).collect();
    for (algo, layout) in [(AlgoKind::Naive, Layout::Nchw), (AlgoKind::Naive, Layout::Nhwc)] {
        let a = algo.build();
        let x = Tensor4::random(p.input_dims(), layout, 81);
        let f = Tensor4::random(p.filter_dims(), layout, 82);
        let packed = a.prepare(&f, &p, layout).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor4::zeros(p.output_dims(), layout);
        a.run_prepacked(&x, &packed, &p, &mut out, &mut ws, Epilogue::BiasRelu(&bias)).unwrap();
        let expect = reference_with_epilogue(&x, &f, &p, layout, Some(&bias), true);
        assert!(
            expect.allclose(&out, 1e-4, 1e-4),
            "{algo} {layout}: diff {}",
            expect.max_abs_diff(&out)
        );
    }
}
