//! CNN inference: a VGG-style stack run through every algorithm × layout,
//! cross-verified, with per-configuration throughput — the "which layout
//! should my model use?" answer a framework integrator needs.
//!
//! ```bash
//! cargo run --release --example cnn_inference [edge] [batch]
//! ```

use im2win::bench_harness::{fmt_time, measure};
use im2win::conv::AlgoKind;
use im2win::model::zoo;
use im2win::prelude::*;
use im2win::tensor::Dims;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let edge: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let x = Tensor4::random(Dims::new(batch, 3, edge, edge), Layout::Nchw, 7);
    println!("vgg_stack inference, input {}x3x{edge}x{edge}\n", batch);

    // Reference logits from the naive oracle.
    let oracle = zoo::vgg_stack(Layout::Nchw, AlgoKind::Naive, edge, 42)?;
    let flops = oracle.flops(batch)?;
    println!("model: {} conv FLOPs per batch: {:.2} GFLOP", oracle.name, flops as f64 / 1e9);
    let expect = oracle.forward(&x)?;

    println!(
        "\n{:<8} {:<7} {:>12} {:>10} {:>12}",
        "algo", "layout", "latency", "GFLOPS", "max|diff|"
    );
    let mut best: Option<(f64, String)> = None;
    for algo in AlgoKind::BENCHED {
        for layout in Layout::ALL {
            // The paper benches im2col only on the PyTorch layouts.
            if algo == AlgoKind::Im2col && matches!(layout, Layout::Chwn | Layout::Chwn8) {
                continue;
            }
            let m = zoo::vgg_stack(layout, algo, edge, 42)?;
            let y = m.forward(&x)?;
            let diff = expect.max_abs_diff(&y);
            assert!(diff < 2e-2, "{algo} {layout} disagrees: {diff}");
            let r = measure(3, || {
                std::hint::black_box(m.forward(&x).unwrap());
            });
            println!(
                "{:<8} {:<7} {:>12} {:>10.2} {:>12.2e}",
                algo.name(),
                layout.to_string(),
                fmt_time(r.best_s),
                flops as f64 / r.best_s / 1e9,
                diff
            );
            let key = format!("{} {}", algo.name(), layout);
            if best.as_ref().map(|(b, _)| r.best_s < *b).unwrap_or(true) {
                best = Some((r.best_s, key));
            }
        }
    }
    let (t, key) = best.unwrap();
    println!("\nfastest configuration: {key} ({})", fmt_time(t));
    println!("(paper Fig. 4: all twelve per-layer winners use the NHWC layout)");
    Ok(())
}
