//! End-to-end driver: train the JAX/Pallas TinyNet **from Rust** via the
//! AOT train-step artifact, then cross-check inference against the native
//! Rust im2win kernels — proving all three layers compose:
//!
//!   L1 Pallas im2win kernel  ─┐ lowered once (make artifacts)
//!   L2 JAX TinyNet fwd/bwd   ─┴─> artifacts/tinynet_train.hlo.txt
//!   L3 this Rust binary: data pipeline, training loop, metrics,
//!      and a final logits cross-check PJRT-vs-rust-kernels.
//!
//! The dataset is synthetic 10-class "template + noise" CIFAR-scale data
//! (no real dataset ships offline); the task is genuinely learnable and
//! the loss curve is the E2E validation artifact recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [steps]
//! ```

use anyhow::{bail, Context, Result};
use im2win::conv::AlgoKind;
use im2win::model::{global_avg_pool, linear, max_pool2d, relu_inplace, Model};
use im2win::prelude::*;
use im2win::runtime::{artifact_path, literal_to_vec, PjrtRuntime};
use im2win::tensor::Dims;
use im2win::testutil::Rng;

const BATCH: usize = 16; // must match aot.py TRAIN_BATCH
const FWD_BATCH: usize = 4; // must match aot.py FWD_BATCH
const IMG: usize = 32;
const CLASSES: usize = 10;
const LR: f32 = 0.1;
const TEMPLATE_SCALE: f32 = 0.9;
const NOISE_SCALE: f32 = 0.35;

/// Synthetic dataset: one fixed random template per class, samples are
/// `0.9·template + 0.35·noise` — learnable to ~100% accuracy in a few
/// hundred SGD steps (tuned in python/tests first).
struct Synth {
    templates: Vec<Vec<f32>>, // [class][3*32*32]
    rng: Rng,
}

impl Synth {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let templates = (0..CLASSES)
            .map(|_| (0..3 * IMG * IMG).map(|_| rng.f32()).collect())
            .collect();
        Synth { templates, rng }
    }

    /// Next batch: images `[n, 3, 32, 32]` flattened NCHW + labels.
    fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(n * 3 * IMG * IMG);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let class = self.rng.int(0, CLASSES - 1);
            ys.push(class as i32);
            for &t in &self.templates[class] {
                xs.push(TEMPLATE_SCALE * t + NOISE_SCALE * self.rng.f32());
            }
        }
        (xs, ys)
    }
}

/// He-initialized weights matching python/compile/model.py::param_shapes.
/// Conv weights are OHWI `[co, hf, wf, ci]`, head is `[10, 32]`.
struct Weights {
    w1: Vec<f32>, // 16*3*3*3
    w2: Vec<f32>, // 32*3*3*16
    w3: Vec<f32>, // 32*3*3*32
    wl: Vec<f32>, // 10*32
}

impl Weights {
    fn init(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // Kaiming-uniform: U(-a, a) with a = sqrt(6/fan_in) has the same
        // variance (2/fan_in) as the He-normal init the JAX model uses.
        let mut he = |len: usize, fan_in: usize, scale: f32| -> Vec<f32> {
            let s = if scale > 0.0 { scale } else { (6.0 / fan_in as f32).sqrt() };
            (0..len).map(|_| rng.f32() * s).collect()
        };
        Weights {
            w1: he(16 * 3 * 3 * 3, 3 * 3 * 3, 0.0),
            w2: he(32 * 3 * 3 * 16, 3 * 3 * 16, 0.0),
            w3: he(32 * 3 * 3 * 32, 3 * 3 * 32, 0.0),
            wl: he(10 * 32, 32, 0.01),
        }
    }

    fn literals(&self) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            lit(&self.w1, &[16, 3, 3, 3])?,
            lit(&self.w2, &[32, 3, 3, 16])?,
            lit(&self.w3, &[32, 3, 3, 32])?,
            lit(&self.wl, &[10, 32])?,
        ])
    }

    /// OHWI `[co, hf, wf, ci]` -> rust filter tensor (logical co,ci,h,w).
    fn conv_filter(data: &[f32], co: usize, k: usize, ci: usize) -> Tensor4 {
        Tensor4::from_fn(Dims::new(co, ci, k, k), Layout::Nhwc, |o, c, u, v| {
            data[((o * k + u) * k + v) * ci + c]
        })
    }
}

fn lit(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// TinyNet forward through the native Rust kernels with the given weights.
fn rust_forward(w: &Weights, x: &Tensor4) -> Result<Tensor4> {
    let algo = AlgoKind::Im2win;
    let layout = Layout::Nhwc;
    let p1 = ConvParams::new(1, 3, 32, 32, 16, 3, 3, 1)?;
    let p2 = ConvParams::new(1, 16, 15, 15, 32, 3, 3, 1)?;
    let p3 = ConvParams::new(1, 32, 6, 6, 32, 3, 3, 1)?;
    let model = Model::new("tinynet-e2e", layout, 3, 32, 32)
        .conv(p1, algo, &Weights::conv_filter(&w.w1, 16, 3, 3))?
        .relu()
        .max_pool(2, 2)?
        .conv(p2, algo, &Weights::conv_filter(&w.w2, 32, 3, 16))?
        .relu()
        .max_pool(2, 2)?
        .conv(p3, algo, &Weights::conv_filter(&w.w3, 32, 3, 32))?
        .relu()
        .global_avg_pool()
        .linear(w.wl.clone(), 10)?;
    // Silence "unused import" pedantry while keeping ops in the public API.
    let _ = (relu_inplace, max_pool2d, global_avg_pool, linear);
    model.forward(x).map_err(Into::into)
}

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500);
    let rt = PjrtRuntime::cpu()?;
    let train = rt
        .load_hlo_text(artifact_path("tinynet_train"))
        .context("loading train artifact (run `make artifacts`)")?;
    let fwd = rt.load_hlo_text(artifact_path("tinynet_fwd"))?;
    println!("loaded {} and {} on {}", train.source, fwd.source, rt.platform());

    let mut data = Synth::new(11);
    let mut w = Weights::init(5);

    println!("\ntraining TinyNet for {steps} steps (batch {BATCH}, lr {LR}):");
    let mut losses: Vec<f32> = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (xs, ys) = data.batch(BATCH);
        let x = lit(&xs, &[BATCH as i64, 3, IMG as i64, IMG as i64])?;
        let y = xla::Literal::vec1(&ys).reshape(&[BATCH as i64])?;
        let mut inputs = vec![x, y];
        inputs.extend(w.literals()?);
        inputs.push(lit(&[LR], &[])? /* scalar lr */);
        let outs = train.execute(&inputs)?;
        if outs.len() != 5 {
            bail!("train step returned {} outputs, expected 5", outs.len());
        }
        let loss = literal_to_vec(&outs[0])?[0];
        w.w1 = literal_to_vec(&outs[1])?;
        w.w2 = literal_to_vec(&outs[2])?;
        w.w3 = literal_to_vec(&outs[3])?;
        w.wl = literal_to_vec(&outs[4])?;
        losses.push(loss);
        if step % 25 == 0 || step == steps - 1 {
            println!("  step {step:>4}  loss {loss:.4}");
        }
        if !loss.is_finite() {
            bail!("training diverged at step {step}");
        }
    }
    // Fresh batches every step: compare smoothed start vs end of the curve.
    let k = (steps / 10).clamp(1, 25);
    let head: f32 = losses[..k].iter().sum::<f32>() / k as f32;
    let tail: f32 = losses[losses.len() - k..].iter().sum::<f32>() / k as f32;
    println!(
        "trained in {:.1}s: mean loss {head:.4} (first {k}) -> {tail:.4} (last {k})",
        t0.elapsed().as_secs_f64()
    );
    if steps >= 100 && tail >= head {
        bail!("loss did not decrease — E2E training failed");
    }

    // Evaluation: accuracy on fresh data through the PJRT forward pass.
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut agree_diff = 0f32;
    for _ in 0..8 {
        let (xs, ys) = data.batch(FWD_BATCH);
        let x = lit(&xs, &[FWD_BATCH as i64, 3, IMG as i64, IMG as i64])?;
        let mut inputs = vec![x];
        inputs.extend(w.literals()?);
        let outs = fwd.execute(&inputs)?;
        let logits = literal_to_vec(&outs[0])?; // [n, 10]
        // Cross-check: the same batch through the native Rust im2win path.
        let xt = Tensor4::from_logical(Dims::new(FWD_BATCH, 3, IMG, IMG), Layout::Nhwc, &xs);
        let rust_logits = rust_forward(&w, &xt)?;
        for (i, &label) in ys.iter().enumerate() {
            let row = &logits[i * CLASSES..(i + 1) * CLASSES];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == label as usize);
            total += 1;
            for c in 0..CLASSES {
                agree_diff = agree_diff.max((row[c] - rust_logits.get(i, c, 0, 0)).abs());
            }
        }
    }
    println!("\neval accuracy on fresh synthetic data: {correct}/{total} ({:.0}%)", 100.0 * correct as f64 / total as f64);
    println!("PJRT logits vs native Rust im2win kernels: max|diff| = {agree_diff:.2e}");
    if agree_diff > 1e-2 {
        bail!("rust and PJRT inference disagree");
    }
    if correct * 2 <= total {
        bail!("accuracy {:.0}% not better than chance x5", 100.0 * correct as f64 / total as f64);
    }
    println!("\nE2E OK: L1 Pallas kernel -> L2 JAX train step -> L3 rust loop all agree.");
    Ok(())
}
