//! Quickstart: one convolution through the public API, three ways.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use im2win::bench_harness::{fmt_time, measure};
use im2win::prelude::*;

fn main() -> Result<()> {
    // conv9 of the paper's Table I at a laptop-friendly batch.
    let p = ConvParams::new(8, 64, 56, 56, 64, 3, 3, 1)?;
    println!("problem: {p}  ({:.2} GFLOP)", p.flops() as f64 / 1e9);

    // Tensors carry an explicit physical layout; NHWC is the paper's best.
    let layout = Layout::Nhwc;
    let input = Tensor4::random(p.input_dims(), layout, 1);
    let filter = Tensor4::random(p.filter_dims(), layout, 2);

    // The paper's method ...
    let im2win = Im2winConv::new();
    let y = im2win.run(&input, &filter, &p)?;

    // ... the no-extra-memory baseline ...
    let direct = DirectConv::new();
    let y_direct = direct.run(&input, &filter, &p)?;

    // ... and the GEMM lowering (PyTorch-style).
    let im2col = Im2colConv::new();
    let y_col = im2col.run(&input, &filter, &p)?;

    // All three agree.
    assert!(y.allclose(&y_direct, 1e-4, 1e-4));
    assert!(y.allclose(&y_col, 1e-4, 1e-4));
    println!("all three algorithms agree: max|diff| = {:.2e}", y.max_abs_diff(&y_col));

    // Quick timing comparison (warmup + best of 5, as the paper measures).
    for (name, algo) in [
        ("im2win", &im2win as &dyn ConvAlgorithm),
        ("direct", &direct),
        ("im2col", &im2col),
    ] {
        let mut out = Tensor4::zeros(p.output_dims(), layout);
        let r = measure(5, || algo.run_into(&input, &filter, &p, &mut out).unwrap());
        println!(
            "  {name:<8} best {:>10}   {:>7.2} GFLOPS",
            fmt_time(r.best_s),
            r.gflops(p.flops())
        );
    }
    Ok(())
}
