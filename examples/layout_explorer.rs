//! Layout explorer: how the four tensor layouts behave on one problem.
//!
//! Shows the paper's §III story numerically: unit-stride dimensions,
//! transformation costs, im2win window-tensor growth, CHWN8 padding, and
//! per-layout conv performance on a Table I layer.
//!
//! ```bash
//! cargo run --release --example layout_explorer [layer] [batch]
//! ```

use im2win::bench_harness::{fmt_time, measure};
use im2win::conv::im2win::{im2win_dims, im2win_transform};
use im2win::coordinator::layers;
use im2win::metrics::MemoryScope;
use im2win::prelude::*;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layer_name = args.first().map(String::as_str).unwrap_or("conv9");
    let batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let layer = layers::by_name(layer_name)
        .unwrap_or_else(|| panic!("unknown layer {layer_name} (conv1..conv12)"));
    let p = layer.scaled_params(batch, 4);
    println!("=== {layer_name} at CI scale: {p} ===\n");

    println!("layout properties:");
    for layout in Layout::ALL {
        let dims = p.input_dims();
        println!(
            "  {layout:<6} unit-stride dim: {:<2} storage: {:>9} floats{}",
            layout.unit_stride_dim(),
            layout.storage_len(dims),
            if layout.storage_len(dims) != dims.count() {
                format!("  (padded from {} — batch rounded to 8)", dims.count())
            } else {
                String::new()
            }
        );
    }

    println!("\nim2win window tensor (paper Algorithm 1):");
    let wd = im2win_dims(&p);
    println!(
        "  input {} -> window {}  ({:.2}x growth; im2col would be {:.2}x)",
        p.input_dims(),
        wd,
        wd.count() as f64 / p.input_dims().count() as f64,
        (p.h_f * p.w_f * p.h_out() * p.w_out()) as f64 / (p.h_in * p.w_in) as f64,
    );

    println!("\nlayout transformation costs (NCHW source):");
    let src = Tensor4::random(p.input_dims(), Layout::Nchw, 1);
    for layout in [Layout::Nhwc, Layout::Chwn, Layout::Chwn8] {
        let r = measure(5, || {
            std::hint::black_box(src.to_layout(layout));
        });
        println!("  NCHW -> {layout:<6} {:>10}", fmt_time(r.best_s));
    }

    println!("\nim2win transform cost + memory per layout:");
    for layout in Layout::ALL {
        let x = src.to_layout(layout);
        let scope = MemoryScope::start();
        let win = im2win_transform(&x, &p);
        let bytes = scope.peak_extra_bytes();
        drop(win);
        let r = measure(5, || {
            std::hint::black_box(im2win_transform(&x, &p));
        });
        println!(
            "  {layout:<6} {:>10}   window tensor {:>8.2} MiB",
            fmt_time(r.best_s),
            bytes as f64 / (1024.0 * 1024.0)
        );
    }

    println!("\nim2win convolution, per layout (best of 5):");
    let algo = Im2winConv::new();
    for layout in Layout::ALL {
        let x = src.to_layout(layout);
        let f = Tensor4::random(p.filter_dims(), layout, 2);
        let mut out = Tensor4::zeros(p.output_dims(), layout);
        let r = measure(5, || algo.run_into(&x, &f, &p, &mut out).unwrap());
        println!(
            "  {layout:<6} {:>10}   {:>7.2} GFLOPS",
            fmt_time(r.best_s),
            r.gflops(p.flops())
        );
    }
    Ok(())
}
